"""Admission control: bounded queue, deadline shedding, degradation ladder.

The controller is exercised with an injectable virtual clock so every
prediction and deadline decision is deterministic — no sleeps, no timing
margins.
"""
import numpy as np
import pytest

from repro.serve.admission import (
    AdmissionConfig,
    AdmissionController,
    RequestShed,
    validate_query,
)
from repro.serve.batching import RequestBatcher, StreamingServer
from repro.serve.distributed import merge_partial_results
from repro.stream import StreamingIndex


class VirtualClock:
    # far ahead of the real monotonic clock: deadlines stamped from this
    # clock stay live unless a test zeroes it on purpose
    def __init__(self):
        self.now = 1.0e9

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


def _controller(clock=None, **over):
    cfg = AdmissionConfig(**{
        "max_queue": 8, "default_deadline_s": 1.0,
        "min_batches_for_prediction": 1, **over,
    })
    kw = {} if clock is None else {"clock": clock}
    return AdmissionController(cfg, batch_size=4, **kw), cfg


class TestController:
    def test_admits_under_bound(self):
        clock = VirtualClock()
        adm, _ = _controller(clock)
        deadline = adm.try_admit(queue_depth=0)
        assert deadline == clock.now + 1.0
        assert adm.admitted == 1 and adm.shed == 0

    def test_queue_full_sheds(self):
        clock = VirtualClock()
        adm, cfg = _controller(clock)
        with pytest.raises(RequestShed) as ei:
            adm.try_admit(queue_depth=cfg.max_queue)
        assert ei.value.reason == "queue_full"
        assert adm.shed == 1

    def test_predicted_wait_sheds_doomed_requests(self):
        clock = VirtualClock()
        adm, _ = _controller(clock)
        adm.observe_batch(0.5)       # 1 batch = 500ms
        # depth 7 → ceil(8/4)=2 batches ahead → 1.0s forecast > 0.9*deadline
        with pytest.raises(RequestShed) as ei:
            adm.try_admit(queue_depth=7, deadline_s=1.0)
        assert ei.value.reason == "predicted_wait"
        # the same depth with a relaxed deadline is admitted
        adm.try_admit(queue_depth=7, deadline_s=10.0)

    def test_cold_model_never_wait_sheds(self):
        clock = VirtualClock()
        adm, _ = _controller(clock, min_batches_for_prediction=3)
        adm.observe_batch(99.0)      # huge, but only 1 observation
        assert adm.predicted_wait(7) == 0.0
        adm.try_admit(queue_depth=7, deadline_s=0.01)   # no shed while cold

    def test_ema_tracks_service_time(self):
        clock = VirtualClock()
        adm, _ = _controller(clock)
        for _ in range(50):
            adm.observe_batch(0.1)
        w1 = adm.predicted_wait(0)
        assert w1 == pytest.approx(0.1, rel=0.05)
        for _ in range(50):
            adm.observe_batch(0.2)
        assert adm.predicted_wait(0) > w1

    def test_degradation_ladder_levels(self):
        clock = VirtualClock()
        adm, cfg = _controller(clock)    # max_queue=8 → rungs at 4 and 6.4
        assert adm.level(0) == 0
        assert adm.level(3) == 0
        assert adm.level(4) == 1
        assert adm.level(7) == 2


class TestBatcherIntegration:
    def test_shed_leaves_no_queue_trace(self):
        clock = VirtualClock()
        adm, cfg = _controller(clock)
        b = RequestBatcher(4, 8, admission=adm)
        for _ in range(cfg.max_queue):
            b.submit(np.zeros(8, np.float32), 0.0, 1.0)
        with pytest.raises(RequestShed):
            b.submit(np.zeros(8, np.float32), 0.0, 1.0)
        assert b.pending == cfg.max_queue
        # shed requests consume no request ids: the next admitted request
        # continues the sequence
        batch = b.next_batch(force=True)
        assert batch is not None and batch[3] == [0, 1, 2, 3]

    def test_expired_requests_dropped_at_batch_formation(self):
        clock = VirtualClock()
        adm, _ = _controller(clock)
        b = RequestBatcher(4, 8, admission=adm)
        # admission stamps absolute deadlines from the *virtual* clock;
        # frozen at 0 with a zero budget, the deadline is already in the
        # past of the real monotonic clock the batcher drops against
        clock.now = 0.0
        b.submit(np.zeros(8, np.float32), 0.0, 1.0, deadline_s=0.0)
        b.submit(np.zeros(8, np.float32), 0.0, 1.0, deadline_s=1e9)
        batch = b.next_batch(force=True)
        assert b.last_expired == [0]
        assert batch is not None and batch[3] == [1]
        assert adm.shed == 1

    def test_validation_rejects_nonfinite(self):
        b = RequestBatcher(4, 8)
        with pytest.raises(ValueError, match="non-finite"):
            b.submit(np.full(8, np.nan, np.float32), 0.0, 1.0)
        with pytest.raises(ValueError, match="non-finite"):
            b.submit(np.zeros(8, np.float32), np.nan, 1.0)
        with pytest.raises(ValueError, match="dim"):
            b.submit(np.zeros(4, np.float32), 0.0, 1.0)
        assert b.pending == 0

    def test_validate_query_allows_sentinels_when_unordered(self):
        q = validate_query(np.zeros(8, np.float32), 1.0, -1.0,
                           require_ordered=False)
        assert q.dtype == np.float32
        with pytest.raises(ValueError):
            validate_query(np.zeros(8, np.float32), 1.0, -1.0)


def _small_index(n=60, seed=0):
    rng = np.random.default_rng(seed)
    idx = StreamingIndex(8, "containment", node_capacity=256,
                         delta_capacity=64, edge_capacity=16)
    for _ in range(n):
        s, t = np.sort(rng.uniform(0.0, 100.0, 2))
        idx.insert(rng.standard_normal(8).astype(np.float32),
                   float(s), float(t))
    return idx


class TestServerLadder:
    def test_step_downgrades_plan_under_pressure(self, monkeypatch):
        adm, _ = _controller()     # real clock: deadlines must stay live
        idx = _small_index()
        srv = StreamingServer(idx, batch_size=4, k=5, timeout_s=0.0,
                              admission=adm)
        seen = []
        real_search = idx.search

        def spy(*a, **kw):
            seen.append((kw.get("plan"), kw.get("planner_config")))
            return real_search(*a, **kw)

        monkeypatch.setattr(idx, "search", spy)
        rng = np.random.default_rng(1)

        def burst(n):
            # generous deadline: the first step's jit compile lands in the
            # EMA, and this test is about the ladder, not wait shedding
            for _ in range(n):
                srv.submit(rng.standard_normal(8).astype(np.float32),
                           10.0, 90.0, deadline_s=120.0)

        burst(2)                       # depth 2 → level 0
        srv.step(force=True)
        burst(5)                       # depth 5 → level 1
        srv.step(force=True)
        burst(7)                       # depth 7 → level 2
        srv.step(force=True)
        plans = [p for p, _ in seen]
        cfgs = [c for _, c in seen]
        assert plans == ["auto", "auto", "graph"]
        assert cfgs[0] is None
        assert cfgs[1] is not None and cfgs[1].wide_max_fraction == 0.0

    def test_all_admitted_requests_answered(self):
        adm, cfg = _controller(max_queue=6)
        idx = _small_index()
        srv = StreamingServer(idx, batch_size=4, k=5, timeout_s=0.0,
                              admission=adm)
        rng = np.random.default_rng(2)
        admitted = 0
        for _ in range(20):
            try:
                srv.submit(rng.standard_normal(8).astype(np.float32),
                           10.0, 90.0)
                admitted += 1
            except RequestShed:
                pass
        out = {}
        while srv.batcher.pending:
            out.update(srv.step(force=True))
        assert len(out) == admitted
        assert adm.shed == 20 - admitted > 0


class TestPartialMerge:
    def _shard(self, ids, dists):
        return (np.asarray(ids, np.int32)[None, :],
                np.asarray(dists, np.float32)[None, :])

    def test_merge_all_present_equals_global_topk(self):
        a = self._shard([3, 9, -1], [0.1, 0.5, np.inf])
        b = self._shard([7, 2, 4], [0.05, 0.3, 0.9])
        out = merge_partial_results([a, b], k=3)
        assert not out.degraded and out.missing_shards == []
        np.testing.assert_array_equal(out.ids[0], [7, 3, 2])

    def test_merge_with_missing_shard_flags_degraded(self):
        a = self._shard([3, 9], [0.1, 0.5])
        out = merge_partial_results([a, None], k=2)
        assert out.degraded and out.missing_shards == [1]
        np.testing.assert_array_equal(out.ids[0], [3, 9])

    def test_merge_all_missing_is_empty_not_crash(self):
        out = merge_partial_results([None, None], k=4)
        assert out.degraded and out.missing_shards == [0, 1]
        assert out.ids.shape == (0, 4)

    def test_padding_sorts_last(self):
        a = self._shard([-1, -1], [0.0, 0.0])   # bogus dists on padding
        b = self._shard([5, -1], [0.7, 0.0])
        out = merge_partial_results([a, b], k=2)
        np.testing.assert_array_equal(out.ids[0], [5, -1])
        assert out.dists[0, 1] == np.inf
