"""Fault handling: request batching sentinels + speculative shard dispatch."""
import time

import numpy as np

from repro.serve.batching import RequestBatcher, SpeculativeDispatcher


def test_batcher_pads_with_noop_sentinels():
    b = RequestBatcher(batch_size=4, dim=3)
    b.submit(np.ones(3), 1.0, 5.0)
    b.submit(np.ones(3), 2.0, 6.0)
    q, s_q, t_q, rids, n_real = b.next_batch()
    assert q.shape == (4, 3) and n_real == 2 and rids == [0, 1]
    # sentinel rows have s_q > t_q => empty valid set => no-op on device
    assert np.all(s_q[2:] > t_q[2:])
    assert b.next_batch() is None


def test_batcher_splits_overflow():
    b = RequestBatcher(batch_size=2, dim=1)
    for i in range(5):
        b.submit(np.zeros(1), 0.0, 1.0)
    sizes = []
    while (batch := b.next_batch()) is not None:
        sizes.append(batch[4])
    assert sizes == [2, 2, 1]


def test_speculative_dispatch_on_slow_shard():
    calls = {"primary": 0, "replica": 0}

    def fast(x):
        calls["primary"] += 1
        return x + 1

    def slow(x):
        calls["primary"] += 1
        time.sleep(0.05)
        return x + 1

    def replica(x):
        calls["replica"] += 1
        return x + 1

    d = SpeculativeDispatcher(
        primary=[fast, slow], replicas=[replica, replica], deadline_s=0.01
    )
    out = d.call_all(2, 10)
    assert out == [11, 11]
    assert d.respeculated == [1]          # only the slow shard re-dispatched
    assert calls["replica"] == 1


def test_speculative_dispatch_on_failing_shard():
    def boom(x):
        raise RuntimeError("shard down")

    def replica(x):
        return x * 2

    d = SpeculativeDispatcher(primary=[boom], replicas=[replica], deadline_s=1.0)
    assert d.call_all(1, 21) == [42]
    assert d.respeculated == [0]
