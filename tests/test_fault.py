"""Fault handling: request batching sentinels, speculative shard dispatch,
injected faults, and the overload/backoff paths (ISSUE 7)."""
import threading
import time

import numpy as np
import pytest

from repro.serve.batching import (
    RequestBatcher,
    SpeculativeDispatcher,
    StreamingServer,
)


def test_batcher_pads_with_noop_sentinels():
    b = RequestBatcher(batch_size=4, dim=3)
    b.submit(np.ones(3), 1.0, 5.0)
    b.submit(np.ones(3), 2.0, 6.0)
    q, s_q, t_q, rids, n_real = b.next_batch()
    assert q.shape == (4, 3) and n_real == 2 and rids == [0, 1]
    # sentinel rows have s_q > t_q => empty valid set => no-op on device
    assert np.all(s_q[2:] > t_q[2:])
    assert b.next_batch() is None


def test_batcher_splits_overflow():
    b = RequestBatcher(batch_size=2, dim=1)
    for i in range(5):
        b.submit(np.zeros(1), 0.0, 1.0)
    sizes = []
    while (batch := b.next_batch()) is not None:
        sizes.append(batch[4])
    assert sizes == [2, 2, 1]


def test_speculative_dispatch_on_slow_shard():
    calls = {"primary": 0, "replica": 0}

    def fast(x):
        calls["primary"] += 1
        return x + 1

    def slow(x):
        calls["primary"] += 1
        time.sleep(0.05)
        return x + 1

    def replica(x):
        calls["replica"] += 1
        return x + 1

    d = SpeculativeDispatcher(
        primary=[fast, slow], replicas=[replica, replica], deadline_s=0.01
    )
    out = d.call_all(2, 10)
    assert out == [11, 11]
    assert d.respeculated == [1]          # only the slow shard re-dispatched
    assert calls["replica"] == 1


def test_speculative_dispatch_on_failing_shard():
    def boom(x):
        raise RuntimeError("shard down")

    def replica(x):
        return x * 2

    d = SpeculativeDispatcher(primary=[boom], replicas=[replica], deadline_s=1.0)
    assert d.call_all(1, 21) == [42]
    assert d.respeculated == [0]


def test_batcher_timeout_holds_partial_batch_then_flushes():
    """A positive timeout holds a partial batch inside the window (None),
    flushes it once the oldest request has aged past the timeout, and
    counts the flush in ``repro_batch_timeout_flushes_total``."""
    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    b = RequestBatcher(batch_size=4, dim=2, timeout_s=0.05, registry=reg)
    b.submit(np.ones(2), 1.0, 5.0)
    assert b.next_batch() is None          # young partial batch: held
    assert b.pending == 1                  # nothing was consumed
    time.sleep(0.06)
    batch = b.next_batch()                 # oldest request aged out: flush
    assert batch is not None and batch[4] == 1
    assert np.all(batch[1][1:] > batch[2][1:])   # padding is sentinel rows
    assert reg.counter("repro_batch_timeout_flushes_total").value() == 1
    # a FULL batch never waits on the timeout
    for _ in range(4):
        b.submit(np.ones(2), 1.0, 5.0)
    assert b.next_batch()[4] == 4
    assert reg.counter("repro_batch_timeout_flushes_total").value() == 1


def test_batcher_force_overrides_timeout():
    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    b = RequestBatcher(batch_size=8, dim=2, timeout_s=60.0, registry=reg)
    b.submit(np.ones(2), 1.0, 5.0)
    assert b.next_batch() is None
    batch = b.next_batch(force=True)
    assert batch is not None and batch[4] == 1
    # forced flush is not a timeout flush
    assert reg.counter("repro_batch_timeout_flushes_total").value() == 0
    assert reg.counter("repro_batch_padding_rows_total").value() == 7


def test_streaming_server_occupancy_extremes():
    """Sentinel padding at occupancy 1/B and B/B through the full
    StreamingServer path: results only for real requests, padding waste
    and occupancy recorded per batch."""
    from repro.data import make_dataset
    from repro.obs import MetricsRegistry
    from repro.stream import StreamingIndex

    dim = 8
    vecs, s, t = make_dataset(60, dim, seed=21)
    idx = StreamingIndex(
        dim, "overlap", node_capacity=128, delta_capacity=64,
        edge_capacity=48, M=6, Z=24,
    )
    idx.insert_batch(vecs, s, t)
    idx.compact()
    reg = MetricsRegistry()
    srv = StreamingServer(idx, batch_size=4, k=3, beam=16, registry=reg)

    rid = srv.submit(vecs[7], float(s.min()) - 1.0, float(t.max()) + 1.0)
    out = srv.drain()                       # occupancy 1/4: 3 sentinel rows
    assert set(out) == {rid}
    ids, d = out[rid]
    assert ids.shape == (3,) and np.all(ids >= 0)
    assert reg.counter("repro_batch_padding_rows_total").value() == 3
    occ = reg.histogram("repro_batch_occupancy")
    assert occ.summary()["count"] == 1 and occ.summary()["min"] == 1.0

    rids = [
        srv.submit(vecs[i], float(s.min()) - 1.0, float(t.max()) + 1.0)
        for i in range(4)
    ]
    out = srv.step()                        # occupancy 4/4: flushes untimed
    assert set(out) == set(rids)
    assert reg.counter("repro_batch_padding_rows_total").value() == 3
    assert occ.summary()["count"] == 2 and occ.summary()["max"] == 4.0
    assert reg.histogram(
        "repro_request_latency_seconds"
    ).summary()["count"] == 5
    assert reg.gauge("repro_epoch").value() == idx.epoch


def test_speculative_dispatch_split_accounting():
    """Replica wins are attributed to their cause: deadline misses and
    failures land in separate lists and separate counter labels."""
    from repro.obs import MetricsRegistry

    def fast(x):
        return x

    def slow(x):
        time.sleep(0.05)
        return x

    def boom(x):
        raise RuntimeError("shard down")

    def replica(x):
        return x

    reg = MetricsRegistry()
    d = SpeculativeDispatcher(
        primary=[fast, slow, boom],
        replicas=[replica, replica, replica],
        deadline_s=0.01,
        registry=reg,
    )
    assert d.call_all(3, 7) == [7, 7, 7]
    assert d.deadline_misses == [1]
    assert d.failures == [2]
    assert d.respeculated == [1, 2]        # combined, in dispatch order
    c = reg.counter("repro_speculative_dispatch_total")
    assert c.value(outcome="primary") == 1
    assert c.value(outcome="replica_win_deadline") == 1
    assert c.value(outcome="replica_win_failure") == 1
    lat = reg.histogram("repro_shard_call_seconds")
    assert lat.summary(shard="0")["count"] == 1
    assert lat.summary(shard="1")["count"] == 1


# --- ISSUE 7: injected faults, races, partial results --------------------------


def test_batcher_submit_next_batch_race_4_threads():
    """Regression: ``_pending`` used to be mutated without a lock. Four
    submitter threads hammer one batcher while a consumer drains; every
    submitted request must come out exactly once, none lost, none
    duplicated."""
    b = RequestBatcher(batch_size=8, dim=4)
    n_per_thread = 200
    errors = []

    def submitter(tid):
        try:
            for i in range(n_per_thread):
                b.submit(np.full(4, tid, np.float32), 0.0, 1.0)
        except Exception as e:      # pragma: no cover - the failure mode
            errors.append(e)

    threads = [threading.Thread(target=submitter, args=(t,)) for t in range(4)]
    seen = []
    for th in threads:
        th.start()
    deadline = time.monotonic() + 30.0
    while (any(th.is_alive() for th in threads) or b.pending) \
            and time.monotonic() < deadline:
        batch = b.next_batch(force=True)
        if batch is not None:
            seen.extend(batch[3])
    for th in threads:
        th.join()
    assert not errors
    assert len(seen) == 4 * n_per_thread, "requests lost or duplicated"
    assert len(set(seen)) == len(seen), "request ids duplicated"


def test_fault_injector_is_deterministic():
    from repro.fault import FaultInjector, FaultSpec

    def schedule(seed):
        inj = FaultInjector(seed, sleep=lambda s: None)
        inj.add("p", FaultSpec("error", probability=0.3))
        fires = []
        for i in range(50):
            try:
                inj.on("p")
            except Exception:
                fires.append(i)
        return fires

    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)


def test_fault_injector_max_hits_heals():
    from repro.fault import FaultInjector, FaultSpec
    from repro.fault.inject import InjectedFault

    inj = FaultInjector(0)
    inj.add("x", FaultSpec("error", max_hits=2))
    hits = 0
    for _ in range(5):
        try:
            inj.on("x")
        except InjectedFault:
            hits += 1
    assert hits == 2        # transient fault: heals after max_hits


def test_compaction_failure_backs_off_and_recovers():
    """An injected build failure must not surface through the serving
    loop: the old epoch keeps serving, retries back off, and a later
    clean attempt swaps the epoch."""
    from repro.fault import FaultInjector, FaultSpec
    from repro.stream import CompactionPolicy, StreamingIndex

    rng = np.random.default_rng(0)
    idx = StreamingIndex(
        8, "containment", node_capacity=256, delta_capacity=64,
        edge_capacity=16,
        policy=CompactionPolicy(max_delta_fraction=0.02, min_mutations=8),
    )
    for _ in range(32):
        s, t = np.sort(rng.uniform(0, 100, 2))
        idx.insert(rng.standard_normal(8).astype(np.float32),
                   float(s), float(t))
    srv = StreamingServer(idx, batch_size=4, k=5, timeout_s=0.0,
                          compaction_backoff_s=0.005)
    epoch0 = idx.epoch
    inj = FaultInjector(0)
    inj.add("build", FaultSpec("error", max_hits=1))
    with inj.injected(idx, "build_epoch", "build"):
        assert srv.maybe_compact_async()
        srv._worker.join()
        # reap the failure: no raise, backoff scheduled instead
        started = srv.maybe_compact_async()
        assert not started
        assert srv.last_compaction_error is not None
        assert idx.epoch == epoch0, "failed build must not swap the epoch"
        # after the backoff window a clean attempt lands
        deadline = time.monotonic() + 15.0
        while idx.epoch == epoch0 and time.monotonic() < deadline:
            if srv.maybe_compact_async() and srv._worker is not None:
                srv._worker.join()
                srv.maybe_compact_async()
            time.sleep(0.002)
    assert idx.epoch > epoch0
    assert srv._fail_count == 0


def test_join_compaction_still_raises_for_explicit_callers():
    """The backoff path must not swallow failures from callers that ask
    for them: ``join_compaction`` keeps the raise contract."""
    from repro.fault import FaultInjector, FaultSpec
    from repro.fault.inject import InjectedFault
    from repro.stream import CompactionPolicy, StreamingIndex

    rng = np.random.default_rng(1)
    idx = StreamingIndex(
        8, "containment", node_capacity=256, delta_capacity=64,
        edge_capacity=16,
        policy=CompactionPolicy(max_delta_fraction=0.02, min_mutations=8),
    )
    for _ in range(32):
        s, t = np.sort(rng.uniform(0, 100, 2))
        idx.insert(rng.standard_normal(8).astype(np.float32),
                   float(s), float(t))
    srv = StreamingServer(idx, batch_size=4, k=5)
    inj = FaultInjector(0)
    inj.add("build", FaultSpec("error"))
    with inj.injected(idx, "build_epoch", "build"):
        assert srv.maybe_compact_async()
        with pytest.raises(InjectedFault):
            srv.join_compaction()


def test_call_all_partial_returns_none_for_dead_pair():
    def ok(x):
        return ("ok", x)

    def boom(x):
        raise RuntimeError("primary down")

    def boom2(x):
        raise RuntimeError("replica down")

    d = SpeculativeDispatcher(
        primary=[ok, boom], replicas=[ok, boom2], deadline_s=0.5,
    )
    results, missing = d.call_all_partial(2, 42)
    assert results[0] == ("ok", 42)
    assert results[1] is None and missing == [1]


def test_call_shard_partial_replica_saves_shard():
    def boom(x):
        raise RuntimeError("primary down")

    def ok(x):
        return x * 2

    d = SpeculativeDispatcher(primary=[boom], replicas=[ok], deadline_s=0.5)
    results, missing = d.call_all_partial(1, 21)
    assert results == [42] and missing == []


def test_poison_vector_rejected_before_device():
    from repro.fault import poison_vector
    from repro.stream import StreamingIndex

    idx = StreamingIndex(8, "containment", node_capacity=256,
                         delta_capacity=64, edge_capacity=16)
    rng = np.random.default_rng(2)
    for _ in range(8):
        s, t = np.sort(rng.uniform(0, 100, 2))
        idx.insert(rng.standard_normal(8).astype(np.float32),
                   float(s), float(t))
    srv = StreamingServer(idx, batch_size=4, k=5)
    for kind in ("nan", "inf", "-inf"):
        with pytest.raises(ValueError, match="non-finite"):
            srv.submit(poison_vector(8, kind=kind), 10.0, 90.0)
    with pytest.raises(ValueError, match="non-finite"):
        srv.submit(rng.standard_normal(8).astype(np.float32),
                   float("nan"), 90.0)
    assert srv.batcher.pending == 0


def test_chaos_scenario_tiny_smoke():
    """The CI chaos entry point end-to-end with a fixed seed."""
    from repro.fault.chaos import run_chaos

    summary = run_chaos(0, tiny=True)
    assert summary["ok"], summary
