"""Property tests (hypothesis) over the whole search stack: for random
datasets, relations, and query intervals, both the host search and the
batched device search must (a) return only predicate-valid objects, and
(b) agree with brute force on the nearest valid object whenever the beam
covers the valid set."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import EntryTable, build_udg, get_relation, search_query
from repro.data import make_vectors
from repro.search import batched_udg_search, export_device_graph

RELS = ["containment", "overlap", "both_after", "both_before"]


def _build(seed, rel, n=80, d=6):
    rng = np.random.default_rng(seed)
    vecs = make_vectors(n, d, seed=seed)
    s = rng.uniform(0, 50, n).astype(np.float32).astype(np.float64)
    t = s + rng.uniform(0, 20, n).astype(np.float32).astype(np.float64)
    g, _ = build_udg(vecs, s, t, rel, M=6, Z=24, K_p=4)
    return vecs, s, t, g, EntryTable(g)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 50),
    rel=st.sampled_from(RELS),
    sq=st.floats(0, 60, allow_nan=False, width=32),
    width=st.floats(0, 40, allow_nan=False, width=32),
    qseed=st.integers(0, 1000),
)
def test_host_search_valid_and_finds_nearest(seed, rel, sq, width, qseed):
    vecs, s, t, g, et = _build(seed % 3, rel)  # few cached builds
    relation = get_relation(rel)
    q = make_vectors(1, vecs.shape[1], seed=qseed)[0]
    tq = sq + width
    ids, dists = search_query(g, q, sq, tq, 5, 64, et)
    mask = relation.valid_mask(s, t, sq, tq)
    for i in ids:
        assert mask[i]
    valid = np.where(mask)[0]
    if valid.size:
        d = np.sum((vecs[valid] - q) ** 2, axis=1)
        nearest = int(valid[np.argmin(d)])
        assert ids.size > 0
        # with beam 64 >> |valid| in most draws, the nearest must be found;
        # tolerate approximation only when the valid set is large
        if valid.size <= 32:
            assert nearest in ids.tolist()
    else:
        assert ids.size == 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 20), rel=st.sampled_from(["containment", "overlap"]))
def test_batched_matches_host_results(seed, rel):
    vecs, s, t, g, et = _build(seed % 2, rel)
    dg = export_device_graph(g, et)
    rng = np.random.default_rng(seed)
    nq = 8
    qv = make_vectors(nq, vecs.shape[1], seed=seed + 99)
    sq = rng.uniform(0, 40, nq)
    tq = sq + rng.uniform(5, 30, nq)
    bids, _ = batched_udg_search(dg, qv, sq, tq, k=5, beam=48, use_ref=True)
    for i in range(nq):
        hids, _ = search_query(g, qv[i], sq[i], tq[i], 5, 48, et)
        got = set(int(x) for x in bids[i] if x >= 0)
        want = set(int(x) for x in hids)
        # identical valid sets + exhaustive small-graph beams => same top-k
        inter = len(got & want)
        assert inter >= max(len(want) - 1, 0), (i, got, want)
