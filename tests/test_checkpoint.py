"""Checkpointing: atomicity, verification, retention, async, resharding."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import CheckpointManager, load_checkpoint, save_checkpoint


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))},
        "step": jnp.asarray(7, dtype=jnp.int32),
    }


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), 7, st, extra={"note": "x"})
    loaded, step, extra = load_checkpoint(str(tmp_path), st)
    assert step == 7 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_hash_verification_catches_corruption(tmp_path):
    st = _state()
    path = save_checkpoint(str(tmp_path), 1, st)
    man = json.load(open(os.path.join(path, "manifest.json")))
    man["hash"] = "0" * 64
    json.dump(man, open(os.path.join(path, "manifest.json"), "w"))
    with pytest.raises(IOError):
        load_checkpoint(path, st)


def test_missing_key_detected(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), 1, st)
    bigger = dict(st, extra_leaf=jnp.zeros((2,)))
    with pytest.raises(KeyError):
        load_checkpoint(str(tmp_path), bigger)


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_0000000003", "step_0000000004"]
    _, step, _ = mgr.restore_latest(_state())
    assert step == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(5, _state(5))
    mgr.wait()
    _, step, _ = mgr.restore_latest(_state())
    assert step == 5


def test_atomic_no_partial_on_existing(tmp_path):
    """A second save of the same step atomically replaces the first."""
    st = _state(1)
    save_checkpoint(str(tmp_path), 9, st)
    st2 = _state(2)
    save_checkpoint(str(tmp_path), 9, st2)
    loaded, _, _ = load_checkpoint(str(tmp_path), st2)
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.asarray(st2["params"]["w"]))
