"""Crash-safe segmented tier: coordinated per-segment durability, snapshot
integrity, quarantine + degraded serving, self-healing rebuild.

The ISSUE-10 acceptance criterion pinned here: a ``SegmentedStreamingIndex``
recovered from its durability directory serves **bit-identically** to a
never-crashed oracle — including crashes BETWEEN two segment snapshots of
one coordinated checkpoint and torn WAL tails in a subset of cells — and
an integrity-failed segment is quarantined (searches exact over the
survivors, flagged via ``missing_segments``, zero scheduler recompiles)
rather than failing recovery.
"""
import os

import numpy as np
import pytest

from repro.core.predicates import DominanceSpace, get_relation
from repro.fault import corrupt_byte, truncate_file
from repro.obs.metrics import get_registry
from repro.scale import (
    CorruptManifestError,
    SegmentGrid,
    SegmentedStreamingIndex,
    build_segmented_index,
    read_manifest,
    write_manifest,
)
from repro.scale.durability import grid_from_manifest, segment_dir
from repro.stream.index import CompactionPolicy
from repro.stream.wal import WriteAheadLog

DIM = 8
KW = dict(node_capacity=256, delta_capacity=64, edge_capacity=16)
POLICY = CompactionPolicy(max_delta_fraction=0.05, min_mutations=16)
BK = dict(M=6, Z=24, K_p=4)


def _dataset(n=140, seed=0, span=100.0):
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((n, DIM)).astype(np.float32)
    s = rng.uniform(0.0, span * 0.6, n)
    t = s + rng.uniform(1.0, span * 0.4, n)
    return vecs, s, t


def _grid(relation, s, t, cells_per_axis=2):
    rel = get_relation(relation)
    return SegmentGrid.from_space(
        DominanceSpace.from_intervals(rel, s, t), cells_per_axis
    )


def _make(relation, grid, storage=None, **over):
    kw = dict(KW, policy=POLICY, build_kwargs=dict(BK), **BK)
    kw.update(over)
    return SegmentedStreamingIndex(
        DIM, relation, grid, storage_dir=storage, **kw
    )


def _queries(nq=6, seed=9):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((nq, DIM)).astype(np.float32)
    return q, np.full(nq, 20.0), np.full(nq, 80.0)


def _recover(root, **over):
    kw = dict(policy=POLICY, build_kwargs=dict(BK))
    kw.update(over)
    return SegmentedStreamingIndex.recover(str(root), **kw)


def _assert_parity(a, b, msg=""):
    q, sq, tq = _queries()
    ia, da = a.search(q, sq, tq, k=7)
    ib, db = b.search(q, sq, tq, k=7)
    np.testing.assert_array_equal(ia, ib, err_msg=msg)
    np.testing.assert_array_equal(da, db, err_msg=msg)


def _close_wals(idx):
    for w in idx._wals:
        if w is not None:
            w.close()


# --- manifest -----------------------------------------------------------------


class TestManifest:
    def test_round_trip(self, tmp_path):
        vecs, s, t = _dataset(seed=1)
        grid = _grid("overlap", s, t)
        man = {
            "generation": 3, "relation": "overlap", "dim": DIM,
            "node_capacity": 256, "delta_capacity": 64,
            "edge_capacity": 16, "M": 6, "Z": 24, "K_p": 4,
            "grid": {
                "edges_x": [int(v) for v in grid.edges_x],
                "edges_y": [int(v) for v in grid.edges_y],
                "vals_x": [float(v) for v in grid.vals_x],
                "vals_y": [float(v) for v in grid.vals_y],
            },
            "segments": [{"snapshot": None, "digest": None, "lsn": 0}] * 4,
        }
        write_manifest(str(tmp_path), man)
        got = read_manifest(str(tmp_path))
        assert got == man
        g2 = grid_from_manifest(got["grid"])
        np.testing.assert_array_equal(g2.edges_x, grid.edges_x)
        # the outer value edges are ±inf and must round-trip through JSON
        np.testing.assert_array_equal(g2.vals_x, grid.vals_x)
        np.testing.assert_array_equal(g2.vals_y, grid.vals_y)

    def test_missing_raises_file_not_found(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            read_manifest(str(tmp_path))

    @pytest.mark.parametrize("damage", ["crc", "magic", "short", "json"])
    def test_corruption_detected(self, tmp_path, damage):
        write_manifest(str(tmp_path), {"generation": 0, "segments": []})
        path = os.path.join(str(tmp_path), "MANIFEST")
        if damage == "crc":
            corrupt_byte(path, os.path.getsize(path) - 2)
        elif damage == "magic":
            corrupt_byte(path, 0)
        elif damage == "short":
            truncate_file(path, 5)
        else:
            corrupt_byte(path, 10)   # inside the JSON payload -> CRC fails
        with pytest.raises(CorruptManifestError):
            read_manifest(str(tmp_path))

    def test_fresh_dir_refuses_existing_manifest(self, tmp_path):
        vecs, s, t = _dataset(seed=2)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid, storage=str(tmp_path))
        _close_wals(idx)
        with pytest.raises(RuntimeError, match="recover"):
            _make("overlap", grid, storage=str(tmp_path))


# --- input boundary (satellite 1) ----------------------------------------------


class TestInsertValidation:
    def setup_method(self):
        vecs, s, t = _dataset(seed=3)
        self.grid = _grid("overlap", s, t)
        self.idx = _make("overlap", self.grid)

    def test_rejects_non_finite_intervals(self):
        v = np.zeros(DIM, np.float32)
        for s, t in ((np.nan, 5.0), (1.0, np.inf), (-np.inf, 2.0)):
            with pytest.raises(ValueError):
                self.idx.insert(v, s, t)
        assert self.idx.live_count == 0

    def test_rejects_non_finite_vectors(self):
        v = np.zeros(DIM, np.float32)
        for bad in (np.nan, np.inf, -np.inf):
            v2 = v.copy()
            v2[3] = bad
            with pytest.raises(ValueError, match="non-finite"):
                self.idx.insert(v2, 1.0, 5.0)
        assert self.idx.live_count == 0

    def test_rejects_shape_mismatch(self):
        with pytest.raises(ValueError):
            self.idx.insert(np.zeros(DIM + 1, np.float32), 1.0, 5.0)
        with pytest.raises(ValueError):
            self.idx.insert_batch(np.zeros((3, DIM), np.float32),
                                  np.zeros(2), np.ones(2))

    def test_batch_rejects_atomically(self):
        """One bad row rejects the WHOLE batch before any routing: no
        partial application, no ids burned."""
        vecs, s, t = _dataset(n=10, seed=4)
        t[7] = np.nan
        with pytest.raises(ValueError):
            self.idx.insert_batch(vecs, s, t)
        assert self.idx.live_count == 0
        t[7] = s[7] + 1.0
        vecs[2, 0] = np.inf
        with pytest.raises(ValueError):
            self.idx.insert_batch(vecs, s, t)
        assert self.idx.live_count == 0

    def test_vectorized_batch_ids_match_row_loop(self):
        """insert_batch routes the whole batch in one vectorized transform
        + grid assignment; the assigned external ids must be bit-identical
        to the historical row-by-row path (per-cell arrival order)."""
        vecs, s, t = _dataset(n=80, seed=5)
        a = _make("overlap", self.grid)
        b = _make("overlap", self.grid)
        ids_batch = a.insert_batch(vecs, s, t)
        ids_loop = np.array([
            b.insert(vecs[i], float(s[i]), float(t[i]))
            for i in range(80)
        ])
        np.testing.assert_array_equal(ids_batch, ids_loop)
        _assert_parity(a, b)


# --- coordinated checkpoint + recovery -----------------------------------------


class TestCheckpointRecovery:
    def _populated(self, tmp_path, relation="overlap", seed=6, **over):
        vecs, s, t = _dataset(seed=seed)
        grid = _grid(relation, s, t)
        idx = _make(relation, grid, storage=str(tmp_path), **over)
        idx.insert_batch(vecs, s, t)
        return idx, grid, (vecs, s, t)

    def test_checkpoint_then_recover_bit_identical(self, tmp_path):
        idx, grid, _ = self._populated(tmp_path)
        gen = idx.save_snapshot()
        assert gen == 1
        # post-checkpoint tail: inserts + deletes replayed from the WALs
        vecs2, s2, t2 = _dataset(n=25, seed=7)
        ids2 = idx.insert_batch(vecs2, s2, t2)
        for e in ids2[:4]:
            assert idx.delete(int(e))
        _close_wals(idx)
        rec, report = _recover(tmp_path)
        assert report.quarantined == []
        assert report.generation == 1
        assert report.records_replayed >= 25 + 4
        assert rec.live_count == idx.live_count
        _assert_parity(rec, idx)
        # recovered index resumes the id namespace without collisions
        before = set(rec.live_ids().tolist())
        rng = np.random.default_rng(0)
        new = rec.insert(rng.standard_normal(DIM).astype(np.float32),
                         10.0, 30.0)
        assert new not in before

    def test_second_checkpoint_prunes_and_gcs(self, tmp_path):
        idx, grid, _ = self._populated(tmp_path,
                                       wal_segment_bytes=1024)
        idx.save_snapshot()
        vecs2, s2, t2 = _dataset(n=30, seed=8)
        idx.insert_batch(vecs2, s2, t2)
        gen = idx.save_snapshot()
        assert gen == 2
        man = read_manifest(str(tmp_path))
        assert man["generation"] == 2
        for ci in range(idx.num_segments):
            names = os.listdir(segment_dir(str(tmp_path), ci))
            snaps = [n for n in names if n.startswith("snapshot-")]
            # old generation GC'd after the manifest publish
            assert snaps == [man["segments"][ci]["snapshot"]]
        _close_wals(idx)
        rec, report = _recover(tmp_path, wal_segment_bytes=1024)
        assert report.quarantined == []
        _assert_parity(rec, idx)

    def test_crash_between_segment_snapshots(self, tmp_path):
        """Crash after SOME cells wrote their new generation but before
        the manifest publish: recovery lands on the previous generation +
        full WAL tails — bit-identical to the pre-crash index — and the
        orphan new-generation files are GC'd."""
        idx, grid, _ = self._populated(tmp_path)
        idx.save_snapshot()
        vecs2, s2, t2 = _dataset(n=20, seed=9)
        idx.insert_batch(vecs2, s2, t2)
        # emulate the partial checkpoint: cells 0..1 wrote generation-2
        # snapshot files, the crash hit before write_manifest
        for ci in (0, 1):
            sub = idx.subs[ci]
            path = os.path.join(segment_dir(str(tmp_path), ci),
                                "snapshot-00000002.npz")
            sub.save_snapshot(path, prune_wal=False)
        _close_wals(idx)
        rec, report = _recover(tmp_path)
        assert report.generation == 1
        assert report.quarantined == []
        _assert_parity(rec, idx)
        for ci in range(rec.num_segments):
            names = os.listdir(segment_dir(str(tmp_path), ci))
            assert not any("00000002" in n for n in names), \
                "orphan generation must be GC'd"

    def test_torn_tails_in_subset_of_cells(self, tmp_path):
        """Torn WAL tails in SOME cells: each cell independently recovers
        its surviving prefix; untouched cells recover everything."""
        idx, grid, _ = self._populated(tmp_path, seed=10)
        idx.save_snapshot()
        vecs2, s2, t2 = _dataset(n=30, seed=11)
        idx.insert_batch(vecs2, s2, t2)
        _close_wals(idx)
        torn = []
        for ci in (0, 2):
            seg = segment_dir(str(tmp_path), ci)
            wals = sorted(n for n in os.listdir(seg)
                          if n.startswith("wal-"))
            path = os.path.join(seg, wals[-1])
            if os.path.getsize(path) > 8:
                truncate_file(path, os.path.getsize(path) - 5)
                torn.append(ci)
        assert torn
        rec, report = _recover(tmp_path)
        assert report.quarantined == []
        assert {r.cell for r in report.segments if r.truncated} == set(torn)
        # oracle: fresh storage-free index replaying each cell's
        # surviving records
        oracle = _make("overlap", grid)
        for ci in range(oracle.num_segments):
            ro = WriteAheadLog(segment_dir(str(tmp_path), ci), sync="never")
            for r in ro.replay(after_lsn=0):
                oracle.subs[ci].apply_record(r)
            ro.close()
        _assert_parity(rec, oracle)

    def test_recovery_is_deterministic(self, tmp_path):
        """Two recoveries of the same directory are bit-identical despite
        concurrent per-cell recovery (thread scheduling must not leak)."""
        idx, grid, _ = self._populated(tmp_path, seed=12)
        idx.save_snapshot()
        vecs2, s2, t2 = _dataset(n=15, seed=13)
        idx.insert_batch(vecs2, s2, t2)
        _close_wals(idx)
        rec1, _ = _recover(tmp_path, max_workers=4)
        _close_wals(rec1)
        rec2, _ = _recover(tmp_path, max_workers=1)
        _assert_parity(rec1, rec2)


# --- snapshot integrity + quarantine -------------------------------------------


class TestQuarantine:
    def _crashed(self, tmp_path, *, seg_bytes=1024, seed=14):
        vecs, s, t = _dataset(seed=seed)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid, storage=str(tmp_path),
                    wal_segment_bytes=seg_bytes)
        idx.insert_batch(vecs, s, t)
        idx.save_snapshot()
        vecs2, s2, t2 = _dataset(n=20, seed=seed + 1)
        idx.insert_batch(vecs2, s2, t2)
        _close_wals(idx)
        return idx, grid

    def test_corrupt_snapshot_full_wal_fallback(self, tmp_path):
        """Corrupt snapshot but the WAL was never pruned (large segments):
        the cell falls back to a full replay — NOT quarantined — and
        serves bit-identically."""
        idx, grid = self._crashed(tmp_path, seg_bytes=1 << 20)
        man = read_manifest(str(tmp_path))
        snap = os.path.join(segment_dir(str(tmp_path), 0),
                            man["segments"][0]["snapshot"])
        corrupt_byte(snap, 80)
        rec, report = _recover(tmp_path, wal_segment_bytes=1 << 20)
        assert report.quarantined == []
        assert "full WAL replay" in report.segments[0].reason
        _assert_parity(rec, idx)

    def test_corrupt_snapshot_pruned_wal_quarantines(self, tmp_path):
        """Corrupt snapshot AND pruned history: the cell is quarantined,
        recovery completes, searches are the exact top-k over survivors
        with the gap flagged, and no quarantined-cell id ever leaks."""
        idx, grid = self._crashed(tmp_path, seg_bytes=1024)
        man = read_manifest(str(tmp_path))
        victim = 0
        snap = os.path.join(segment_dir(str(tmp_path), victim),
                            man["segments"][victim]["snapshot"])
        corrupt_byte(snap, 120)
        rec, report = _recover(tmp_path, wal_segment_bytes=1024)
        assert report.quarantined == [victim]
        assert sorted(rec.quarantined) == [victim]
        q, sq, tq = _queries()
        ids, d, info = rec.search(q, sq, tq, k=7, return_partial=True)
        assert info.degraded
        assert info.missing_segments == [victim]
        C = rec.num_segments
        assert not np.any((ids >= 0) & (ids % C == victim))
        # survivors-exact oracle: the (bit-identical) pre-crash index with
        # the same cell masked out of routing
        idx.quarantine_segment(victim, "oracle mask")
        oid, od, oinfo = idx.search(q, sq, tq, k=7, return_partial=True)
        np.testing.assert_array_equal(ids, oid)
        np.testing.assert_array_equal(d, od)
        # rebuild cannot succeed while the storage stays corrupt
        assert rec.maybe_rebuild() == {victim: False}
        assert victim in rec.quarantined

    def test_wal_corruption_alone_never_quarantines(self, tmp_path):
        idx, grid = self._crashed(tmp_path, seg_bytes=1 << 20)
        seg = segment_dir(str(tmp_path), 1)
        wals = sorted(n for n in os.listdir(seg) if n.startswith("wal-"))
        path = os.path.join(seg, wals[-1])
        corrupt_byte(path, os.path.getsize(path) // 2)
        rec, report = _recover(tmp_path, wal_segment_bytes=1 << 20)
        assert report.quarantined == []

    def test_runtime_quarantine_and_storage_rebuild(self, tmp_path):
        """Runtime fault -> quarantine -> maybe_rebuild self-heals from
        intact storage, lifting the quarantine with full parity and a
        re-primed stack slice."""
        vecs, s, t = _dataset(seed=16)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid, storage=str(tmp_path))
        idx.insert_batch(vecs, s, t)
        idx.maybe_compact()   # give cells a non-empty compacted tier
        idx.save_snapshot()
        q, sq, tq = _queries()
        pre = idx.search(q, sq, tq, k=7)
        st = idx.device_stack()
        hot = int(np.argmax([sub.live_count for sub in idx.subs]))
        idx.quarantine_segment(hot, "poisoned")
        assert hot in idx.quarantined
        # the quarantined slice is scrubbed: all gids -1
        assert np.all(np.asarray(st.part(hot)["gids"]) == -1)
        ids, d, info = idx.search(q, sq, tq, k=7, return_partial=True)
        assert info.missing_segments == [hot] or not info.degraded
        assert idx.maybe_rebuild() == {hot: True}
        assert not idx.quarantined
        post = idx.search(q, sq, tq, k=7)
        np.testing.assert_array_equal(pre[0], post[0])
        np.testing.assert_array_equal(pre[1], post[1])
        # the stack slice was re-primed from the rebuilt cell: it must
        # equal a fresh export of that cell's (non-empty) compacted tier
        sub = idx.subs[hot]
        with sub._lock:
            want = np.where(
                sub._graph_live, sub._graph_ext, -1
            ).astype(np.int32)
        got = np.asarray(st.part(hot)["gids"])
        np.testing.assert_array_equal(got[: want.shape[0]], want)
        assert got.max() >= 0

    def test_memory_only_rebuild_without_storage(self, tmp_path):
        """No storage bound: rebuild falls back to the stashed
        pre-quarantine object's live set (original external ids)."""
        vecs, s, t = _dataset(seed=17)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid)
        ids0 = idx.insert_batch(vecs, s, t)
        hot = int(np.argmax([sub.live_count for sub in idx.subs]))
        live_before = set(idx.subs[hot].live_ids().tolist())
        idx.quarantine_segment(hot, "poisoned")
        assert idx.maybe_rebuild() == {hot: True}
        assert set(idx.subs[hot].live_ids().tolist()) == live_before

    def test_rebuild_backoff_is_seeded_exponential(self, tmp_path):
        """Failed rebuilds walk a seeded exponential-with-jitter ladder —
        retry deadlines strictly grow and stay within the policy bounds."""
        vecs, s, t = _dataset(seed=18)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid, rebuild_backoff_s=0.05,
                    rebuild_backoff_max_s=5.0, rebuild_backoff_seed=3)
        idx.insert_batch(vecs, s, t)
        hot = 0
        idx.quarantine_segment(hot, "poisoned")
        idx._q_src.pop(hot)      # no storage AND no stash -> always fails
        delays = []
        import time as _time
        for fails in range(1, 5):
            idx._q_retry_at[hot] = 0.0      # force eligibility
            before = _time.monotonic()
            assert idx.maybe_rebuild() == {hot: False}
            delays.append(idx._q_retry_at[hot] - before)
            assert idx._q_fails[hot] == fails
        for i, dly in enumerate(delays):
            base = 0.05 * (2 ** i)
            assert 0.5 * base <= dly <= min(base, 5.0) + 0.05
        # deterministic: the same seed reproduces the same jitter ladder
        rng = np.random.default_rng(3)
        expect = [min(0.05 * 2 ** i, 5.0) * (0.5 + 0.5 * rng.random())
                  for i in range(4)]
        np.testing.assert_allclose(delays, expect, atol=0.05)

    def test_insert_into_quarantined_cell_rejected(self, tmp_path):
        vecs, s, t = _dataset(seed=19)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid)
        ids = idx.insert_batch(vecs, s, t)
        rel = get_relation("overlap")
        cell = grid.assign_values(*rel.transform_data(s, t))
        victim = int(cell[0])
        idx.quarantine_segment(victim, "poisoned")
        with pytest.raises(RuntimeError, match="quarantined"):
            idx.insert(vecs[0], float(s[0]), float(t[0]))
        # rows routed elsewhere still insert
        other = int(np.flatnonzero(cell != victim)[0])
        new = idx.insert(vecs[other], float(s[other]), float(t[other]))
        assert new % idx.num_segments != victim

    def test_quarantine_metrics_exported(self, tmp_path):
        reg = get_registry()
        vecs, s, t = _dataset(seed=20)
        grid = _grid("overlap", s, t)
        idx = _make("overlap", grid, storage=str(tmp_path))
        idx.insert_batch(vecs, s, t)
        idx.save_snapshot()
        idx.quarantine_segment(0, "poisoned")
        assert reg.gauge("repro_segments_quarantined").value() >= 1
        idx.maybe_rebuild()
        assert reg.gauge("repro_segments_quarantined").value() == 0
        _close_wals(idx)
        _recover(tmp_path)
        names = reg.names()
        for name in ("repro_recovery_seconds",
                     "repro_wal_replayed_records_total",
                     "repro_snapshot_bytes", "repro_snapshot_seconds"):
            assert name in names, name


# --- batch tier: quarantine through the worklist scheduler ---------------------


class TestBatchTierQuarantine:
    @pytest.fixture(scope="class")
    def env(self):
        from repro.data import make_dataset, make_queries_vectors

        n, d = 600, 8
        vecs, s, t = make_dataset(n, d, seed=31)
        idx = build_segmented_index(vecs, s, t, "overlap",
                                    cells_per_axis=2, M=8, Z=32, K_p=4)
        qv = make_queries_vectors(8, d, seed=4)
        sq = np.full(8, float(np.min(s)))
        tq = np.full(8, float(np.max(t)))
        return dict(idx=idx, vecs=vecs, s=s, t=t, qv=qv, sq=sq, tq=tq)

    def test_degraded_exact_over_survivors_zero_recompiles(self, env):
        from repro.exec import worklist_exec_cache_size

        idx = env["idx"]
        qv, sq, tq = env["qv"], env["sq"], env["tq"]
        full = idx.search(qv, sq, tq, k=9, beam=40, return_route=True)
        victim = int(np.flatnonzero(full[2].any(axis=0))[0])
        # warm the bucket the degraded mix lands in, then pin the count
        idx.quarantine_segment(victim, "poisoned")
        idx.search(qv, sq, tq, k=9, beam=40)
        idx.lift_quarantine(victim)
        warm = worklist_exec_cache_size()

        healthy = idx.search(qv, sq, tq, k=9, beam=40)
        idx.quarantine_segment(victim, "poisoned")
        ids, d, route, info = idx.search(qv, sq, tq, k=9, beam=40,
                                         return_route=True,
                                         return_partial=True)
        assert worklist_exec_cache_size() == warm, "no recompiles allowed"
        assert info.degraded and info.missing_segments == [victim]
        assert not route[:, victim].any()
        # bit parity with the per-segment host-loop oracle under the
        # same quarantine mask (the pinned scheduler-parity contract)
        oid, od = idx.search(qv, sq, tq, k=9, beam=40, scheduler=False)
        np.testing.assert_array_equal(ids, oid)
        np.testing.assert_allclose(d, od)
        # no victim row ever surfaces; every hit is a valid survivor,
        # and the nearest surviving neighbor is always found
        victim_members = set(idx.segments[victim].ids.tolist())
        member = np.zeros(env["vecs"].shape[0], bool)
        for si, seg in enumerate(idx.segments):
            if si != victim:
                member[seg.ids] = True
        rel = get_relation("overlap")
        for b in range(qv.shape[0]):
            got = ids[b][ids[b] >= 0]
            assert not (set(got.tolist()) & victim_members)
            ok = member & np.asarray(
                rel.valid_mask(env["s"], env["t"], sq[b], tq[b]))
            assert np.all(ok[got])
            vids = np.flatnonzero(ok)
            if vids.size:
                dd = np.sum((env["vecs"][vids] - qv[b]) ** 2, axis=1)
                assert vids[np.argmin(dd)] in set(got.tolist())
        idx.lift_quarantine(victim)
        restored = idx.search(qv, sq, tq, k=9, beam=40)
        np.testing.assert_array_equal(healthy[0], restored[0])

    def test_sharded_export_masks_quarantined(self, env):
        """segments_to_sharded_index on a quarantined index: the bad
        shard contributes NOTHING device-side — no entry points, an
        empty planner (routes BRUTE over zero candidates), and a -1
        ``id_map`` row so nothing can ever translate back to its ids."""
        from repro.serve.distributed import segments_to_sharded_index

        idx = env["idx"]
        qv, sq, tq = env["qv"], env["sq"], env["tq"]
        full = idx.search(qv, sq, tq, k=9, beam=40, return_route=True)
        victim = int(np.flatnonzero(full[2].any(axis=0))[0])
        idx.quarantine_segment(victim, "poisoned")
        try:
            sharded, id_map = segments_to_sharded_index(idx)
            assert np.all(id_map[victim] == -1)
            assert np.all(np.asarray(sharded.entry_node)[victim] == -1)
            assert sharded.planners[victim].n == 0
            # survivors keep their export untouched
            other = next(si for si in range(idx.num_segments)
                         if si != victim and idx.segments[si].ids.size)
            np.testing.assert_array_equal(
                id_map[other][: idx.segments[other].ids.size],
                idx.segments[other].ids,
            )
        finally:
            idx.lift_quarantine(victim)


@pytest.mark.slow
def test_sharded_serving_degraded_subprocess():
    """End-to-end shard_map serving of a quarantined segmented index
    (subprocess with forced host devices, as the serving tests do): the
    degraded PartialResult flags the gap and never leaks a victim id."""
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import numpy as np
from repro.data import make_dataset, make_queries_vectors
from repro.launch.mesh import make_host_mesh
from repro.scale import build_segmented_index
from repro.serve.distributed import segments_to_sharded_index, serve_batch

vecs, s, t = make_dataset(600, 8, seed=31)
idx = build_segmented_index(vecs, s, t, "overlap", cells_per_axis=2,
                            M=8, Z=32, K_p=4, quantize_int8=False)
qv = make_queries_vectors(8, 8, seed=4)
sq = np.full(8, float(np.min(s)))
tq = np.full(8, float(np.max(t)))
_, _, route = idx.search(qv, sq, tq, k=9, beam=40, return_route=True)
victim = int(np.flatnonzero(route.any(axis=0))[0])
idx.quarantine_segment(victim, "poisoned")
sh, id_map = segments_to_sharded_index(idx)
mesh = make_host_mesh(model_parallel=sh.num_shards)
out = serve_batch(sh, mesh, qv, sq, tq, k=9, beam=40, id_map=id_map,
                  missing_shards=sorted(idx.quarantined),
                  return_partial=True)
assert out.degraded and out.missing_shards == [victim], out.missing_shards
victims = set(idx.segments[victim].ids.tolist())
leaked = set(int(i) for i in out.ids[out.ids >= 0]) & victims
assert not leaked, leaked
assert np.all(np.isinf(out.dists[out.ids < 0]))
print("OK")
"""
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=os.path.join(repo, "src"),
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
