"""Streaming subsystem: LSM-style online mutations over the UDG.

Covers the ISSUE-1 acceptance criteria:
  * after interleaved inserts/deletes (spanning several compactions), query
    recall on the streamed index is within 1% of a from-scratch UDG rebuilt
    on the same live set — for containment and overlap;
  * deletes never resurface: not from the delta tier, not from graph
    tombstones, and not across a compaction that races the delete;
  * epoch swap under concurrent queries: every query sees one consistent
    epoch (never a deleted id, never an unknown id) and the swap does not
    recompile the jitted serving step.
"""
import threading

import numpy as np
import pytest

from repro.core import EntryTable, build_udg, get_relation
from repro.data import make_dataset, make_queries_vectors
from repro.search import batched_udg_search, export_device_graph
from repro.serve import ShardedStreamingIndex, StreamingServer
from repro.stream import (
    CompactionPolicy,
    StreamingIndex,
    sort_key,
    streaming_search_cache_size,
)

DIM = 16
K = 10
BEAM = 48


def _workload(n=420, seed=0):
    return make_dataset(n, DIM, seed=seed)


def _queries(s, t, nq=16, seed=1):
    """Query vectors + interval predicates spanning narrow to broad."""
    rng = np.random.default_rng(seed)
    qv = make_queries_vectors(nq, DIM, seed=seed)
    lo = rng.uniform(s.min(), s.max(), size=nq)
    width = rng.uniform(0.05, 1.0, size=nq) * (t.max() - s.min())
    return qv, lo, np.minimum(lo + width, t.max() + 1.0)


def _brute_topk(qv, s_q, t_q, vecs, s, t, ext, relation, k=K):
    """Exact top-k external ids over a live set under the predicate."""
    rel = get_relation(relation)
    m = rel.valid_mask(s, t, s_q, t_q)
    if not m.any():
        return set()
    d = ((vecs[m] - qv) ** 2).sum(axis=1)
    return set(int(x) for x in ext[m][np.argsort(d)][:k])


def _recall(results, gts):
    hits = sum(len(set(map(int, r[r >= 0])) & gt) for r, gt in zip(results, gts))
    total = sum(len(gt) for gt in gts)
    return hits / max(total, 1)


def test_sort_key_is_monotone():
    rng = np.random.default_rng(0)
    v = np.concatenate(
        [rng.normal(scale=100.0, size=500), [0.0, -0.0, 1e-30, -1e-30, 1e30, -1e30]]
    ).astype(np.float32)
    k = sort_key(v)
    order_v = np.argsort(v, kind="stable")
    assert np.all(np.diff(k[order_v]) >= 0)
    assert sort_key(-0.0) == sort_key(0.0)


@pytest.mark.parametrize("relation", ["containment", "overlap"])
def test_streamed_recall_matches_rebuild_oracle(relation):
    vecs, s, t = _workload()
    n = vecs.shape[0]
    idx = StreamingIndex(
        DIM, relation, node_capacity=512, delta_capacity=96, edge_capacity=96,
        M=8, Z=32, policy=CompactionPolicy(max_delta_fraction=0.2, min_mutations=24),
    )
    # interleave: insert in chunks, delete stragglers, let the policy compact
    ext_of_row = {}
    deleted = set()
    rng = np.random.default_rng(7)
    for lo in range(0, n, 60):
        hi = min(lo + 60, n)
        for i in range(lo, hi):
            ext_of_row[i] = idx.insert(vecs[i], s[i], t[i])
        alive = [i for i in ext_of_row if i not in deleted]
        for i in rng.choice(alive, size=6, replace=False):
            assert idx.delete(ext_of_row[i])
            deleted.add(i)
        idx.maybe_compact()
    assert idx.epoch >= 1  # at least one compaction actually happened
    assert idx.live_count == n - len(deleted)

    live_rows = np.array(sorted(set(range(n)) - deleted))
    lv, ls, lt = vecs[live_rows], s[live_rows], t[live_rows]
    lext = np.array([ext_of_row[i] for i in live_rows])

    qv, s_q, t_q = _queries(s, t)
    gts = [
        _brute_topk(qv[i], s_q[i], t_q[i], lv, ls, lt, lext, relation)
        for i in range(len(qv))
    ]
    ids, _ = idx.search(qv, s_q, t_q, k=K, beam=BEAM)
    r_stream = _recall(ids, gts)

    # from-scratch oracle: one static UDG over exactly the live set
    g, _ = build_udg(lv, ls, lt, relation, M=8, Z=32)
    dg = export_device_graph(g, EntryTable(g))
    oid, _ = batched_udg_search(dg, qv, s_q, t_q, k=K, beam=BEAM, use_ref=True)
    gts_local = [
        _brute_topk(qv[i], s_q[i], t_q[i], lv, ls, lt, np.arange(len(live_rows)),
                    relation)
        for i in range(len(qv))
    ]
    r_rebuild = _recall(oid, gts_local)

    assert r_stream >= r_rebuild - 0.01, (r_stream, r_rebuild)


def test_deletes_never_resurface_across_compaction():
    vecs, s, t = _workload(n=300, seed=2)
    idx = StreamingIndex(
        DIM, "containment", node_capacity=512, delta_capacity=128,
        edge_capacity=96, M=8, Z=32,
    )
    ext = idx.insert_batch(vecs[:200], s[:200], t[:200])
    qv = make_queries_vectors(4, DIM, seed=3)
    broad = (float(s.min()) - 1.0, float(t.max()) + 1.0)  # everything valid

    def returned_ids():
        ids, _ = idx.search(
            qv, np.full(4, broad[0]), np.full(4, broad[1]), k=K, beam=BEAM
        )
        return set(int(x) for x in ids.ravel() if x >= 0)

    # 1. delete straight from the delta tier
    dead = set(int(e) for e in ext[:30])
    for e in sorted(dead):
        assert idx.delete(e)
    assert not (returned_ids() & dead)
    # 2. compact: tombstoned objects must not be rebuilt into the new epoch
    idx.compact()
    assert not (returned_ids() & dead)
    # 3. delete from the compacted graph tier (soft delete)
    dead2 = set(int(e) for e in ext[30:60])
    for e in sorted(dead2):
        assert idx.delete(e)
    assert not (returned_ids() & (dead | dead2))
    # 4. a delete racing an in-flight compaction is replayed at swap
    job = idx.begin_compaction()
    racing = set(int(e) for e in ext[60:80])
    for e in sorted(racing):
        assert idx.delete(e)
    late = idx.insert_batch(vecs[200:220], s[200:220], t[200:220])
    idx.build_epoch(job)
    idx.finish_compaction(job)
    got = returned_ids()
    assert not (got & (dead | dead2 | racing))
    # post-snapshot inserts survived the swap: still live, and querying an
    # object's own vector under the broad predicate returns it at ~distance 0
    # (the gather-fused path scores via cached norms, ‖c‖²−2q·c+‖q‖², which
    # leaves float-rounding residue where the diff-square form gave exact 0)
    live = set(int(e) for e in idx.live_ids())
    assert set(int(e) for e in late) <= live
    for j in (0, 7, 19):
        ids, d = idx.search(vecs[200 + j], broad[0], broad[1], k=K, beam=BEAM)
        assert int(ids[0]) == int(late[j]) and d[0] <= 1e-4
    # 5. double delete reports False, unknown id reports False
    assert not idx.delete(int(ext[0]))
    assert not idx.delete(10**9)


def test_epoch_swap_under_concurrent_queries_no_recompile():
    vecs, s, t = _workload(n=360, seed=4)
    idx = StreamingIndex(
        DIM, "overlap", node_capacity=512, delta_capacity=128, edge_capacity=96,
        M=8, Z=32, policy=CompactionPolicy(max_delta_fraction=0.05, min_mutations=8),
    )
    srv = StreamingServer(idx, batch_size=4, k=K, beam=BEAM)
    ext = idx.insert_batch(vecs[:240], s[:240], t[:240])
    idx.compact()
    deleted = set(int(e) for e in ext[:40])
    for e in sorted(deleted):
        idx.delete(e)
    for i in range(240, 300):
        idx.insert(vecs[i], s[i], t[i])

    qv = make_queries_vectors(4, DIM, seed=5)
    broad_s = np.full(4, float(s.min()) - 1.0)
    broad_t = np.full(4, float(t.max()) + 1.0)
    cache_before = streaming_search_cache_size()
    epoch_before = idx.epoch

    errors: list = []
    results: list = []
    stop = threading.Event()

    def query_loop():
        try:
            while not stop.is_set():
                ids, _ = idx.search(qv, broad_s, broad_t, k=K, beam=BEAM)
                results.append(ids.copy())
        except BaseException as exc:  # surfaced below
            errors.append(exc)

    qt = threading.Thread(target=query_loop)
    qt.start()
    try:
        assert srv.maybe_compact_async()  # policy fires: 60 delta + 40 dead
        srv.join_compaction()
        # a few more queries strictly after the swap
        for _ in range(3):
            ids, _ = idx.search(qv, broad_s, broad_t, k=K, beam=BEAM)
            results.append(ids.copy())
    finally:
        stop.set()
        qt.join()
    assert not errors, errors
    assert idx.epoch == epoch_before + 1
    # one static shape across the swap: zero new compilations
    assert streaming_search_cache_size() == cache_before
    # every concurrently-issued query saw one consistent epoch: deleted ids
    # never appear, and all ids belong to the (unchanged) live set
    live = set(int(e) for e in idx.live_ids())
    for ids in results:
        got = set(int(x) for x in ids.ravel() if x >= 0)
        assert not (got & deleted)
        assert got <= live


def test_sharded_streaming_round_trip():
    vecs, s, t = _workload(n=240, seed=6)
    sidx = ShardedStreamingIndex(
        DIM, "containment", 2, node_capacity=256, delta_capacity=64,
        edge_capacity=96, M=8, Z=32,
    )
    ext = sidx.insert_batch(vecs, s, t)
    assert len(set(map(int, ext))) == len(ext)  # globally unique ids
    deleted = set(int(e) for e in ext[::5])
    for e in sorted(deleted):
        assert sidx.delete(e)
    while sidx.maybe_compact_shards() >= 0:
        pass
    live_rows = np.array([i for i in range(len(ext)) if int(ext[i]) not in deleted])
    lext = np.array([int(ext[i]) for i in live_rows])
    qv, s_q, t_q = _queries(s, t, nq=8, seed=7)
    ids, d = sidx.search(qv, s_q, t_q, k=K, beam=BEAM)
    gts = [
        _brute_topk(qv[i], s_q[i], t_q[i], vecs[live_rows], s[live_rows],
                    t[live_rows], lext, "containment")
        for i in range(len(qv))
    ]
    assert _recall(ids, gts) >= 0.95
    for row in ids:
        got = set(int(x) for x in row if x >= 0)
        assert not (got & deleted)
