"""Practical constructor (§V-A) + patch edges (§V-B) + Theorem 2 scaling."""
import numpy as np
import pytest

from repro.core import PATCH_VARIANTS, build_udg, build_udg_exact
from repro.data import make_dataset


@pytest.mark.parametrize("leap", ["conservative", "maxleap"])
def test_leap_policies_build_and_label_invariants(leap):
    vecs, s, t = make_dataset(200, 8, seed=1)
    g, rep = build_udg(vecs, s, t, "containment", M=6, Z=24, leap=leap)
    assert rep.num_tuples == g.num_tuples > 0
    for u in range(g.n):
        nbr, l, r, b, e = g.tuples(u)
        assert np.all(l <= r) and np.all(b <= e)
        assert np.all((nbr >= 0) & (nbr < g.n))
        assert np.all(nbr != u)  # no self loops
        # X label right boundary never exceeds either endpoint's X rank
        assert np.all(r <= np.maximum(g.x_rank[u], 0) + g.num_x)  # sanity
        assert np.all(r <= np.minimum(g.x_rank[nbr], g.x_rank[u]))


def test_maxleap_fewer_rounds_than_conservative():
    vecs, s, t = make_dataset(300, 8, seed=2)
    _, rep_c = build_udg(vecs, s, t, "containment", M=6, Z=24, leap="conservative")
    _, rep_m = build_udg(vecs, s, t, "containment", M=6, Z=24, leap="maxleap")
    assert rep_m.sweep_rounds <= rep_c.sweep_rounds


@pytest.mark.parametrize("variant", PATCH_VARIANTS)
def test_patch_variants(variant):
    vecs, s, t = make_dataset(150, 8, seed=3)
    g, rep = build_udg(vecs, s, t, "overlap", M=6, Z=16, K_p=4, patch=variant)
    if variant == "none":
        assert rep.num_patch_tuples == 0
    # patch labels obey the same rectangle invariants
    for u in range(g.n):
        nbr, l, r, b, e = g.tuples(u)
        assert np.all(l <= r) and np.all(b <= e)


def test_full_patch_adds_no_more_than_previous_none():
    vecs, s, t = make_dataset(150, 8, seed=4)
    _, rep_none = build_udg(vecs, s, t, "overlap", M=6, Z=16, patch="none")
    _, rep_full = build_udg(vecs, s, t, "overlap", M=6, Z=16, K_p=4, patch="full")
    assert rep_full.num_tuples >= rep_none.num_tuples
    # patch edges bounded by O(n M): each object patches at most one range
    assert rep_full.num_patch_tuples <= 2 * rep_full.n * 6


def test_theorem2_rounds_scaling():
    """Expected sweep rounds are O(n log n): rounds/n should grow ~log n,
    far below the O(n) worst case."""
    rates = []
    for n in (100, 400):
        vecs, s, t = make_dataset(n, 8, seed=5)
        _, rep = build_udg_exact(vecs, s, t, "containment", M=4)
        rates.append(rep.sweep_rounds / n)
    # doubling n twice should far-less-than-double rounds/n (log growth)
    assert rates[1] < rates[0] * 2.5
    assert rates[1] < 0.25 * 400  # nowhere near the O(n) worst case


def test_save_load_roundtrip(tmp_path):
    from repro.core import LabeledGraph

    vecs, s, t = make_dataset(80, 8, seed=6)
    g, _ = build_udg(vecs, s, t, "containment", M=5, Z=16)
    path = str(tmp_path / "udg.npz")
    g.save(path)
    g2 = LabeledGraph.load(path)
    assert g2.num_tuples == g.num_tuples
    for u in (0, 7, 42):
        a, b_ = g.tuples(u), g2.tuples(u)
        for x, y in zip(a, b_):
            np.testing.assert_array_equal(x, y)


def test_bad_arguments():
    vecs, s, t = make_dataset(30, 4, seed=0)
    with pytest.raises(ValueError):
        build_udg(vecs, s, t, "containment", leap="bogus")
    with pytest.raises(ValueError):
        build_udg(vecs, s, t, "containment", patch="bogus")
    with pytest.raises(KeyError):
        build_udg(vecs, s, t, "not-a-relation")
