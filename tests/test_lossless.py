"""Theorem 1 (structural lossless emulation) + Lemma 2 (edge validity).

The exact constructor's active subgraph must be edge-identical to the
dedicated insertion-only graph for EVERY canonical state (a, c) — checked
exhaustively over the full U_X x U_Y grid on small datasets across
relations, seeds, and M values.
"""
import numpy as np
import pytest

from repro.core import build_dedicated_reference, build_udg, build_udg_exact
from repro.data import make_dataset


def _check_all_states(g, M):
    for a in range(g.num_x):
        for c in range(g.num_y):
            valid = np.where(g.valid_mask_rank(a, c))[0]
            ref = build_dedicated_reference(g.vectors, valid, g.space.Y, M)
            act = g.active_edge_set(a, c)
            assert act == ref, (
                f"state ({a},{c}): only-UDG={sorted(act - ref)[:4]} "
                f"only-ref={sorted(ref - act)[:4]}"
            )


@pytest.mark.parametrize("relation", ["containment", "overlap", "both_before"])
@pytest.mark.parametrize("seed", [0, 7])
def test_theorem1_lossless_all_states(relation, seed):
    vecs, s, t = make_dataset(48, 8, seed=seed)
    g, _ = build_udg_exact(vecs, s, t, relation, M=4)
    _check_all_states(g, 4)


def test_theorem1_larger_M():
    vecs, s, t = make_dataset(40, 6, seed=11)
    g, _ = build_udg_exact(vecs, s, t, "overlap", M=8)
    _check_all_states(g, 8)


def test_theorem1_with_duplicate_endpoints():
    """Ties in transformed coordinates must not break the induction."""
    rng = np.random.default_rng(5)
    vecs = rng.normal(size=(40, 6)).astype(np.float32)
    s = np.round(rng.uniform(0, 10, 40))   # heavy duplication
    t = s + np.round(rng.uniform(0, 5, 40))
    g, _ = build_udg_exact(vecs, s, t, "containment", M=4)
    _check_all_states(g, 4)


@pytest.mark.parametrize("relation", ["containment", "overlap", "both_after"])
def test_lemma2_edge_validity_practical(relation):
    """Every ACTIVE edge of the practical index connects valid endpoints —
    for every canonical state (Lemma 2 extends to patch edges, §V-B)."""
    vecs, s, t = make_dataset(80, 8, seed=2)
    g, _ = build_udg(vecs, s, t, relation, M=6, Z=24, K_p=4)
    rng = np.random.default_rng(0)
    states = [(int(rng.integers(0, g.num_x)), int(rng.integers(0, g.num_y)))
              for _ in range(60)]
    for a, c in states:
        valid = g.valid_mask_rank(a, c)
        for u, v in g.active_edge_set(a, c):
            assert valid[u] and valid[v], (a, c, u, v)


def test_exact_constructor_with_graph_search_still_valid():
    """Alg. 3 with real UDGSearch (no ASA): Lemma 2 still holds exactly."""
    vecs, s, t = make_dataset(40, 6, seed=4)
    g, _ = build_udg_exact(vecs, s, t, "containment", M=4, use_graph_search=True)
    for a in range(0, g.num_x, 7):
        for c in range(0, g.num_y, 7):
            valid = g.valid_mask_rank(a, c)
            for u, v in g.active_edge_set(a, c):
                assert valid[u] and valid[v]
