"""Multi-device behaviour (subprocess with 8 host-platform devices):
sharded serving parity, DP trainer with/without gradient compression,
elastic checkpoint-restart. Kept in subprocesses so the main test process
retains the real 1-device view."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_sharded_serving_recall_and_id_mapping():
    out = _run(
        """
import numpy as np
from repro.data import make_dataset, make_queries_vectors, generate_queries, ground_truth, recall_at_k
from repro.serve import build_sharded_index, serve_batch
from repro.launch.mesh import make_host_mesh
from repro.core import get_relation

vecs, s, t = make_dataset(1024, 12, seed=0)
qv = make_queries_vectors(16, 12, seed=1)
idx = build_sharded_index(vecs, s, t, "overlap", 4, M=8, Z=32)
mesh = make_host_mesh(model_parallel=4)
qs = ground_truth(generate_queries(qv, s, t, "overlap", 0.05, k=10, seed=2), vecs, s, t)
rel = get_relation("overlap")
for merge in ("all_gather", "tournament"):
    ids, d = serve_batch(idx, mesh, qs.vectors, qs.s_q, qs.t_q, k=10, beam=48, merge=merge)
    for i in range(qs.nq):
        m = rel.valid_mask(s, t, qs.s_q[i], qs.t_q[i])
        assert all(m[j] for j in ids[i] if j >= 0), (merge, i)
    r = recall_at_k(ids, qs)
    assert r >= 0.9, (merge, r)
    print(merge, round(r, 3))
""")
    assert "all_gather" in out and "tournament" in out


@pytest.mark.slow
def test_dp_trainer_and_gradient_compression():
    out = _run(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.train import adamw
from repro.train.dp_trainer import make_dp_train_step

cfg = get_config("llama3.2-1b", smoke=True)
mesh = make_host_mesh(model_parallel=1)   # 8-way DP
rng = np.random.default_rng(0)
batch = {"tokens": rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32)}
batch["labels"] = np.roll(batch["tokens"], -1, axis=1)

losses = {}
for compress in (False, True):
    # fresh params per run: the jitted step donates its state argument
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)
    init_state, step = make_dp_train_step(cfg, opt, mesh, compress_grads=compress)
    state = init_state(params)
    ls = []
    for i in range(8):
        state, m = step(state, batch)
        ls.append(float(m["loss"]))
    losses[compress] = ls
    assert ls[-1] < ls[0], (compress, ls)
# int8-compressed training must track the uncompressed trajectory closely
diff = abs(losses[True][-1] - losses[False][-1])
assert diff < 0.15 * abs(losses[False][0] - losses[False][-1]) + 0.05, losses
print("ok", losses[False][-1], losses[True][-1])
""")
    assert "ok" in out


@pytest.mark.slow
def test_elastic_restart_downscale():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import PartitionSpec as P
from repro.distributed.elastic import ElasticRunner
from repro.train import CheckpointManager, adamw

# toy quadratic model trained data-parallel; elastic 8 -> 4 devices
opt = adamw(lr=0.1, weight_decay=0.0)

def make_mesh(n):
    return jax.make_mesh((n,), ("data",))

def make_step(mesh):
    def step(state, batch):
        def loss_fn(p):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2)
        g = jax.grad(loss_fn)(state["params"])
        new_p, new_o, _ = opt.update(g, state["opt"], state["params"])
        return {"params": new_p, "opt": new_o}
    return jax.jit(step)

def state_specs(mesh):
    return jax.tree_util.tree_map(lambda _: P(),
        {"params": {"w": 0}, "opt": opt.init({"w": jnp.zeros((4,))})})

rng = np.random.default_rng(0)
w0 = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
state = {"params": w0, "opt": opt.init(w0)}
batches = [{"x": rng.normal(size=(8, 4)).astype(np.float32),
            "y": rng.normal(size=(8,)).astype(np.float32)} for _ in range(30)]
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, keep=2)
    runner = ElasticRunner(ckpt=mgr, make_mesh=make_mesh, make_step=make_step,
                           state_specs=state_specs, ckpt_every=5)
    state, steps, restarts = runner.run(state, batches, n_devices=8,
                                        fail_at=17, recover_devices=4)
assert steps == 30 and restarts == 1
print("elastic ok", steps, restarts)
""")
    assert "elastic ok" in out


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The dry-run machinery itself (512 devices) on the cheapest cell."""
    out = _run(
        """
import sys
sys.argv = ["dryrun", "--arch", "llama3.2-1b", "--shape", "decode_32k",
            "--mesh", "single", "--out", "/tmp/dryrun_test"]
from repro.launch.dryrun import main
main()
import json
r = json.load(open("/tmp/dryrun_test/llama3.2-1b.decode_32k.pod16x16.json"))
assert r["ok"], r.get("error")
assert r["roofline"]["bottleneck"] in ("compute", "memory", "collective")
print("dryrun ok", r["roofline"]["bottleneck"])
""", devices=1, timeout=900)
    assert "dryrun ok" in out
