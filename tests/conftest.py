"""Shared fixtures. NOTE: no XLA_FLAGS here by design — tests and benches
must see the real single CPU device; only launch/dryrun.py forces 512
placeholder devices (and multi-device tests spawn subprocesses)."""
import numpy as np
import pytest

from repro.data import make_dataset, make_queries_vectors


@pytest.fixture(scope="session")
def small_dataset():
    """(vectors, s, t): 1500 x 16, uniform capped intervals."""
    return make_dataset(1500, 16, seed=0)


@pytest.fixture(scope="session")
def query_vectors():
    return make_queries_vectors(24, 16, seed=1)


@pytest.fixture(scope="session")
def tiny_dataset():
    """(vectors, s, t): 120 x 8 — small enough for exhaustive state checks."""
    return make_dataset(120, 8, seed=3)


def pad_ids(ids, k):
    ids = np.asarray(ids, dtype=np.int64)
    if ids.shape[0] >= k:
        return ids[:k]
    return np.pad(ids, (0, k - ids.shape[0]), constant_values=-1)
