"""Sharding-rule invariants on the abstract production meshes (no devices
needed): every assigned axis must divide its dimension, for every parameter
/ optimizer / cache / batch leaf of every architecture and shape. This is
the class of bug (e.g. 8 KV heads on a 16-way model axis) that otherwise
only surfaces deep inside the 512-device dry-run."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_supported
from repro.distributed.sharding import (
    batch_spec,
    cache_specs,
    logits_spec,
    opt_state_specs,
    param_specs,
)
from repro.distributed.compat import abstract_mesh
from repro.models import init_decode_state, init_params_shapes
from repro.train import adamw

MESHES = [
    abstract_mesh({"data": 16, "model": 16}),
    abstract_mesh({"pod": 2, "data": 16, "model": 16}),
]


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    return int(np.prod([mesh.shape[a] for a in axes]))


def _check(tree, specs, mesh, what):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    assert len(leaves) == len(spec_leaves), what
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) <= len(leaf.shape), (what, path, spec, leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            size = _axis_size(mesh, axes)
            assert dim % size == 0, (
                f"{what}: {jax.tree_util.keystr(path)} dim {dim} not "
                f"divisible by {axes} (={size})"
            )


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_and_opt_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    params = init_params_shapes(cfg)
    pspecs = param_specs(params, cfg, mesh)
    _check(params, pspecs, mesh, f"{arch} params")
    opt = adamw()
    opt_sh = jax.eval_shape(opt.init, params)
    ospecs = opt_state_specs(opt_sh, pspecs)
    _check(opt_sh, ospecs, mesh, f"{arch} opt")


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, mesh, shape):
    cfg = get_config(arch)
    ok, _ = shape_supported(cfg, shape)
    if not ok:
        pytest.skip("long_500k rule")
    sh = SHAPES[shape]
    cache = jax.eval_shape(
        lambda: init_decode_state(cfg, sh.global_batch, sh.seq_len)
    )
    cspecs = cache_specs(cache, cfg, mesh)
    _check(cache, cspecs, mesh, f"{arch} {shape} cache")


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
def test_batch_and_logits_specs(mesh):
    for b in (1, 32, 128, 256):
        spec = batch_spec(mesh, (b, 4096))
        assert b % _axis_size(mesh, tuple(spec)[0]) == 0
    for b, v in ((1, 256000), (128, 2048), (32, 262144)):
        spec = logits_spec(mesh, (b, v))
        assert b % _axis_size(mesh, tuple(spec)[0]) == 0
        assert v % _axis_size(mesh, tuple(spec)[-1]) == 0
