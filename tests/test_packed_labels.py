"""Packed-metadata layout: pack/unpack round-trip, rank-width guard and
fallback, packed-vs-int32 search parity across all five relations, and the
memoized DeviceGraph.device() bundle."""
import numpy as np
import pytest

from repro.core import build_index
from repro.core.predicates import RELATIONS
from repro.data import generate_queries, ground_truth, make_queries_vectors, recall_at_k
from repro.search import (
    batched_udg_search,
    export_device_graph,
    pack_labels,
    unpack_labels,
)
from repro.search import device_graph as dgmod


def test_pack_unpack_bitwise_round_trip():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 1 << 16, size=(37, 11, 4)).astype(np.int32)
    # force the 16-bit corners into the sample
    labels[0, 0] = [0, 0, 0, 0]
    labels[0, 1] = [(1 << 16) - 1] * 4
    packed = pack_labels(labels)
    assert packed.dtype == np.uint32 and packed.shape == (37, 11, 2)
    np.testing.assert_array_equal(unpack_labels(packed), labels)
    # words are exactly lo | hi << 16
    assert packed[0, 1, 0] == 0xFFFFFFFF and packed[0, 0, 0] == 0


def test_pack_labels_rejects_out_of_range():
    bad_hi = np.zeros((2, 2, 4), np.int32)
    bad_hi[0, 0, 1] = 1 << 16
    with pytest.raises(ValueError):
        pack_labels(bad_hi)
    bad_neg = np.zeros((2, 2, 4), np.int32)
    bad_neg[1, 1, 2] = -1
    with pytest.raises(ValueError):
        pack_labels(bad_neg)
    with pytest.raises(ValueError):
        pack_labels(np.zeros((2, 3), np.int32))       # wrong trailing dim
    with pytest.raises(ValueError):
        unpack_labels(np.zeros((2, 4), np.uint32))    # wrong trailing dim


@pytest.fixture(scope="module")
def tiny_index(tiny_dataset):
    vecs, s, t = tiny_dataset
    g, et, _ = build_index(vecs, s, t, "overlap", M=6, Z=24, K_p=4)
    return vecs, s, t, g, et


def test_export_packs_by_default(tiny_index):
    vecs, s, t, g, et = tiny_index
    dg = export_device_graph(g, et)
    assert dg.plabels is not None and dg.labels is None
    assert dg.plabels.dtype == np.uint32
    # labels_i32 unpacks (and caches) the oracle layout bitwise
    lab = dg.labels_i32()
    np.testing.assert_array_equal(pack_labels(lab), dg.plabels)
    assert dg.labels_i32() is lab  # cached
    # itemized nbytes counts the packed at-rest layout: 8 bytes/edge
    comp = dg.nbytes_by_component()
    assert comp["labels"] == dg.plabels.nbytes
    assert sum(comp.values()) == dg.nbytes()


def test_rank_width_guard_fallback_round_trip(tiny_index, monkeypatch):
    """A grid over the 16-bit budget must warn + fall back to the int32
    layout (packed_labels=None), raise under packed_labels=True, and the
    fallback index must serve identically to the packed one."""
    vecs, s, t, g, et = tiny_index
    packed_dg = export_device_graph(g, et)
    monkeypatch.setattr(dgmod, "RANK_LIMIT", 4)   # grid no longer "fits"
    with pytest.warns(RuntimeWarning, match="16-bit rank budget"):
        dg = export_device_graph(g, et)
    assert dg.plabels is None and dg.labels is not None
    with pytest.raises(ValueError, match="16-bit rank budget"):
        export_device_graph(g, et, packed_labels=True)
    monkeypatch.undo()
    # fallback layout round-trip: same rectangles, same search results
    np.testing.assert_array_equal(dg.labels, packed_dg.labels_i32())
    qv = make_queries_vectors(8, vecs.shape[1], seed=5)
    qs = generate_queries(qv, s, t, "overlap", 0.1, k=5, seed=6)
    a, da = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                               k=5, beam=24, use_ref=True)
    b, db = batched_udg_search(packed_dg, qs.vectors, qs.s_q, qs.t_q,
                               k=5, beam=24, use_ref=True)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(da, db)
    with pytest.raises(ValueError, match="no packed labels"):
        batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                           k=5, beam=24, use_ref=True, packed=True)


def test_forced_int32_export(tiny_index):
    vecs, s, t, g, et = tiny_index
    dg = export_device_graph(g, et, packed_labels=False)
    assert dg.plabels is None and dg.labels is not None
    assert dg.labels_i32() is dg.labels


@pytest.mark.parametrize("relation", sorted(RELATIONS))
def test_packed_vs_int32_parity_all_relations(tiny_dataset, relation):
    """The packed superkernel path returns bit-identical ids/distances to
    both the int32 fused oracle and the unfused baseline, per relation."""
    if relation == "query_within_data":
        # feasible only with uncapped data intervals at low selectivity
        from repro.data import make_dataset

        vecs, s, t = make_dataset(120, 8, distribution="uncapped", seed=3)
        sigma = 0.05
    else:
        vecs, s, t = tiny_dataset
        sigma = 0.15
    g, et, _ = build_index(vecs, s, t, relation, M=6, Z=24, K_p=4)
    dg = export_device_graph(g, et)
    qv = make_queries_vectors(10, vecs.shape[1], seed=11)
    qs = ground_truth(
        generate_queries(qv, s, t, relation, sigma, k=5, seed=12), vecs, s, t
    )
    packed, d_p = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                     k=5, beam=24, use_ref=True)
    int32, d_i = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                    k=5, beam=24, use_ref=True, packed=False)
    unfused, d_u = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                      k=5, beam=24, use_ref=True, fused=False)
    np.testing.assert_array_equal(packed, int32)
    np.testing.assert_array_equal(packed, unfused)
    np.testing.assert_array_equal(d_p, d_i)
    assert recall_at_k(packed, qs) == recall_at_k(unfused, qs)


def test_device_bundle_memoized(tiny_index):
    vecs, s, t, g, et = tiny_index
    dg = export_device_graph(g, et)
    dev = dg.device()
    assert dg.device() is dev                      # memoized
    assert dev.packed and dev.labels.shape[-1] == 2
    assert dev.scales is None
    np.testing.assert_array_equal(np.asarray(dev.nbr), dg.nbr)
    np.testing.assert_array_equal(np.asarray(dev.labels), dg.plabels)
    lab32 = dg.device_labels_i32()
    assert dg.device_labels_i32() is lab32         # memoized
    np.testing.assert_array_equal(np.asarray(lab32), dg.labels_i32())
    dg.invalidate_device()
    assert dg.device() is not dev                  # rebuilt after invalidate


def test_executor_unfused_oracle_on_packed_export(tiny_index):
    """The planned executor's fused=False parity baseline must serve from
    a packed export (int32 labels derived on demand), matching the fused
    packed path bitwise."""
    from repro.exec import execute_batch

    vecs, s, t, g, et = tiny_index
    dg = export_device_graph(g, et)
    qv = make_queries_vectors(6, vecs.shape[1], seed=21)
    qs = generate_queries(qv, s, t, "overlap", 0.1, k=5, seed=22)
    a, da = execute_batch(dg, qs.vectors, qs.s_q, qs.t_q, k=5, beam=24,
                          use_ref=True, fused=False, plan="graph")
    b, db = execute_batch(dg, qs.vectors, qs.s_q, qs.t_q, k=5, beam=24,
                          use_ref=True, fused=True, plan="graph")
    np.testing.assert_array_equal(a, b)
    # ids bit-equal; distances differ only by the cached-norm float residue
    # (‖c‖²−2qc+‖q‖² vs diff-square — same tolerance as test_streaming)
    np.testing.assert_allclose(da, db, atol=1e-4)


def test_unpack_labels_device_matches_host():
    import jax.numpy as jnp

    from repro.search.device_graph import unpack_labels_device

    rng = np.random.default_rng(7)
    labels = rng.integers(0, 1 << 16, size=(9, 5, 4)).astype(np.int32)
    packed = pack_labels(labels)
    np.testing.assert_array_equal(
        np.asarray(unpack_labels_device(jnp.asarray(packed))), labels)


def test_device_bundle_int8_storage(tiny_index):
    vecs, s, t, g, et = tiny_index
    dg = export_device_graph(g, et, quantize_int8=True)
    dev = dg.device()
    assert dev.table.dtype == np.int8 and dev.scales is not None
    np.testing.assert_array_equal(np.asarray(dev.table), dg.vec_q)
