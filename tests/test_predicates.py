"""Table II semantic mappings + Lemma 1 canonicalization (property tests)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.predicates import (
    RELATIONS,
    DominanceSpace,
    canonical_state_for_query,
    get_relation,
)

RELATION_NAMES = sorted(RELATIONS)


def _intervals(draw, n):
    s = draw(st.lists(st.floats(0, 100, allow_nan=False, width=32),
                      min_size=n, max_size=n))
    ln = draw(st.lists(st.floats(0, 30, allow_nan=False, width=32),
                       min_size=n, max_size=n))
    s = np.asarray(s, dtype=np.float64)
    t = s + np.asarray(ln, dtype=np.float64)
    return s, t


@pytest.mark.parametrize("rel_name", RELATION_NAMES)
@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_mapping_matches_brute_predicate(rel_name, data):
    """Eq.(1) over transformed coords == the original interval predicate."""
    rel = get_relation(rel_name)
    n = data.draw(st.integers(3, 40))
    s, t = _intervals(data.draw, n)
    s_q = data.draw(st.floats(-10, 110, allow_nan=False, width=32))
    t_q = s_q + data.draw(st.floats(0, 60, allow_nan=False, width=32))
    X, Y = rel.transform_data(s, t)
    x_q, y_q = rel.transform_query(s_q, t_q)
    dominance = (X >= x_q) & (Y <= y_q)
    brute = rel.valid_mask(s, t, s_q, t_q)
    np.testing.assert_array_equal(dominance, brute, err_msg=rel_name)


@pytest.mark.parametrize("rel_name", RELATION_NAMES)
@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_lemma1_canonicalization_exact(rel_name, data):
    """Raw and canonical transformed queries select identical valid sets."""
    rel = get_relation(rel_name)
    n = data.draw(st.integers(3, 40))
    s, t = _intervals(data.draw, n)
    space = DominanceSpace.from_intervals(rel, s, t)
    s_q = data.draw(st.floats(-10, 110, allow_nan=False, width=32))
    t_q = s_q + data.draw(st.floats(0, 60, allow_nan=False, width=32))
    x_q, y_q = rel.transform_query(s_q, t_q)
    raw = (space.X >= x_q) & (space.Y <= y_q)
    state = space.canonicalize(x_q, y_q)
    if state is None:
        assert not np.any(raw)
        return
    a, c = state
    canon = space.valid_mask_state(a, c)
    np.testing.assert_array_equal(raw, canon)
    # canonical values come from the data grids
    assert a in space.U_X and c in space.U_Y


def test_query_unmap_roundtrip():
    for name, rel in RELATIONS.items():
        xq, yq = rel.transform_query(3.5, 9.25)
        assert rel.query_unmap(xq, yq) == (3.5, 9.25), name


def test_unknown_relation_raises():
    with pytest.raises(KeyError):
        get_relation("strictly-before")


def test_canonical_state_for_query_empty():
    rel = get_relation("containment")
    s = np.array([10.0, 20.0])
    t = np.array([15.0, 25.0])
    space = DominanceSpace.from_intervals(rel, s, t)
    # query start after every data start -> successor undefined -> empty
    assert canonical_state_for_query(rel, space, 50.0, 60.0) is None
