"""Segmented scale-out index (repro.scale): router completeness, int8+rerank
parity, no-recompile across segment mixes, byte accounting, determinism,
segment-local streaming compaction, and segment-sharded serving.

The load-bearing invariant (property-tested below across all five
relations) is **router completeness**: for every query whose canonical
state exists, every object satisfying ``DominanceSpace.valid_mask_state``
lives in a routed cell. Over-selection is fine; a dropped valid object is
a recall bug. The value-space router (`route_values`, the streaming twin)
is pinned under the same invariant.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import get_relation
from repro.core.build_batched import build_udg_batched
from repro.core.predicates import RELATIONS, DominanceSpace
from repro.data import (
    generate_queries,
    ground_truth,
    make_dataset,
    make_queries_vectors,
    make_vectors,
    recall_at_k,
)
from repro.exec import (
    execute_batch,
    planned_exec_cache_size,
    worklist_exec_cache_size,
)
from repro.scale import (
    SegmentGrid,
    SegmentedIndex,
    SegmentedStreamingIndex,
    build_segmented_index,
    canonicalize_batch,
    dispatch_count,
    merge_fold_cache_size,
    worklist_capacity,
)
from repro.search import export_device_graph
from repro.stream.index import CompactionPolicy

RELATION_NAMES = sorted(RELATIONS)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _intervals(rng, n, T=100.0):
    s = rng.uniform(0, T, n)
    return s, s + rng.uniform(0, 0.3 * T, n)


def _check_router_complete(relname, seed, cells_per_axis, nq=16, n=160):
    """Core completeness check shared by the seeded sweep and the
    hypothesis property test."""
    rng = np.random.default_rng(seed)
    rel = get_relation(relname)
    s, t = _intervals(rng, n)
    X, Y = rel.transform_data(s, t)
    space = DominanceSpace.build(X, Y)
    grid = SegmentGrid.from_space(space, cells_per_axis)
    xr, yr = space.ranks()
    cell = grid.assign_ranks(xr, yr)
    # value-space assignment must agree with rank-space on on-grid points
    np.testing.assert_array_equal(grid.assign_values(X, Y), cell)

    sq, tq = _intervals(rng, nq)
    x_q, y_q = rel.query_map(sq, tq)
    a, c, valid = canonicalize_batch(space, x_q, y_q)
    route_r = grid.route_ranks(a, c, valid)
    route_v = grid.route_values(x_q, y_q, valid)
    for b in range(nq):
        m = np.asarray(rel.valid_mask(s, t, sq[b], tq[b]))
        vids = np.flatnonzero(m)
        if not valid[b]:
            # canonical state missing => valid set provably empty
            assert vids.size == 0, (relname, seed, b)
            assert not route_r[b].any() and not route_v[b].any()
            continue
        for i in vids:
            assert route_r[b, cell[i]], (
                f"{relname} seed={seed} q={b}: valid object {i} "
                f"(cell {cell[i]}) not rank-routed")
            assert route_v[b, cell[i]], (
                f"{relname} seed={seed} q={b}: valid object {i} "
                f"(cell {cell[i]}) not value-routed")


# --- satellite: router completeness (seeded sweep, runs everywhere) -----------


@pytest.mark.parametrize("relname", RELATION_NAMES)
def test_router_completeness_all_relations_seeded(relname):
    for seed in range(4):
        for g in (2, 3, 5):
            _check_router_complete(relname, seed, g)


def test_router_rejects_invalid_rows():
    rng = np.random.default_rng(0)
    rel = get_relation("containment")
    s, t = _intervals(rng, 50)
    space = DominanceSpace.from_intervals(rel, s, t)
    grid = SegmentGrid.from_space(space, 3)
    # query interval far past every datum => canonicalization fails
    x_q, y_q = rel.query_map(np.asarray([1e9]), np.asarray([2e9]))
    a, c, valid = canonicalize_batch(space, x_q, y_q)
    assert not valid[0]
    assert not grid.route_ranks(a, c, valid).any()
    assert not grid.route_values(x_q, y_q, valid).any()


# --- satellite: router completeness (hypothesis property sweep) ---------------


try:
    import hypothesis  # noqa: F401

    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        relname=st.sampled_from(RELATION_NAMES),
        seed=st.integers(0, 10_000),
        g=st.integers(2, 6),
    )
    def test_router_completeness_property(relname, seed, g):
        _check_router_complete(relname, seed, g, nq=8, n=80)

else:

    def test_router_completeness_property():
        pytest.skip("hypothesis not installed")


# --- shared segmented index (module scope amortizes the build) ----------------


@pytest.fixture(scope="module")
def seg_env():
    n, d = 1500, 8
    vecs, s, t = make_dataset(n, d, seed=7)
    idx = build_segmented_index(
        vecs, s, t, "overlap", cells_per_axis=3, M=8, Z=32, K_p=4,
        quantize_int8=True,
    )
    qv = make_queries_vectors(24, d, seed=11)
    qs = ground_truth(
        generate_queries(qv, s, t, "overlap", 0.08, k=10, seed=3), vecs, s, t)
    return dict(vecs=vecs, s=s, t=t, idx=idx, qs=qs)


def test_segmented_builds_real_segments(seg_env):
    idx = seg_env["idx"]
    assert idx.num_segments >= 2
    sizes = idx.segment_sizes()
    assert int(sizes.sum()) == idx.n
    # disjoint, exhaustive membership
    allids = np.concatenate([seg.ids for seg in idx.segments])
    np.testing.assert_array_equal(np.sort(allids), np.arange(idx.n))
    assert idx.quantized and all(seg.dg.vec_q is not None
                                 for seg in idx.segments)


def test_refined_route_keeps_every_valid_objects_segment(seg_env):
    """The hi>0 histogram refinement must stay recall-safe end to end."""
    idx, qs = seg_env["idx"], seg_env["qs"]
    s, t = seg_env["s"], seg_env["t"]
    rel = idx.relation
    cell_of = {int(g): si for si, seg in enumerate(idx.segments)
               for g in seg.ids}
    _, _, route = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10,
                             return_route=True)
    seg_of = np.empty(idx.n, dtype=np.int64)
    for si, seg in enumerate(idx.segments):
        seg_of[seg.ids] = si
    for b in range(qs.nq):
        m = np.asarray(rel.valid_mask(s, t, qs.s_q[b], qs.t_q[b]))
        for i in np.flatnonzero(m):
            assert route[b, seg_of[i]], (b, i)
    assert cell_of  # sanity: membership map non-trivial


def test_segmented_recall_matches_monolithic(seg_env):
    """The n=100k benchmark gate in miniature: segmented recall within
    0.5 pt of the monolithic index at the same beam."""
    vecs, s, t = seg_env["vecs"], seg_env["s"], seg_env["t"]
    idx, qs = seg_env["idx"], seg_env["qs"]
    ids, d = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64)
    seg_recall = recall_at_k(ids, qs)

    g, _ = build_udg_batched(vecs, s, t, "overlap", M=8, Z=32, K_p=4)
    dg = export_device_graph(g)
    mono_ids, _ = execute_batch(dg, qs.vectors, qs.s_q, qs.t_q,
                                k=10, beam=64)
    mono_recall = recall_at_k(np.asarray(mono_ids), qs)
    assert seg_recall >= mono_recall - 0.005, (seg_recall, mono_recall)
    assert seg_recall >= 0.9

    # every returned id must satisfy the predicate
    rel = idx.relation
    for b in range(qs.nq):
        m = np.asarray(rel.valid_mask(s, t, qs.s_q[b], qs.t_q[b]))
        assert all(m[j] for j in ids[b] if j >= 0), b

    # rerank distances are exact f32 distances
    for b in range(qs.nq):
        for col, j in enumerate(ids[b]):
            if j < 0:
                continue
            ref = np.float32(np.sum(
                (vecs[j] - qs.vectors[b]) ** 2, dtype=np.float32))
            assert np.isclose(d[b, col], ref, rtol=1e-5), (b, col)


# --- satellite: int8 + rerank parity across all five relations ----------------


@pytest.mark.parametrize("relname", RELATION_NAMES)
def test_int8_rerank_parity_per_relation(relname):
    n, d = 700, 8
    vecs = make_vectors(n, d, seed=13)
    # wide intervals keep every relation feasible (query_within_data needs
    # data intervals long enough to contain a query interval)
    s, t = _intervals(np.random.default_rng(13), n)
    idx = build_segmented_index(
        vecs, s, t, relname, cells_per_axis=2, M=8, Z=32, K_p=4,
        quantize_int8=True,
    )
    qv = make_queries_vectors(12, d, seed=5)
    qs = ground_truth(
        generate_queries(qv, s, t, relname, 0.1, k=10, seed=9), vecs, s, t)
    ids, _ = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64)
    r = recall_at_k(ids, qs)
    assert r >= 0.9, (relname, r)


def test_int8_rerank_tie_rule_duplicate_vectors():
    """Duplicate vectors => equal exact distances => ties break toward the
    smaller id (the ground-truth ``np.lexsort((ids, d))`` rule)."""
    rng = np.random.default_rng(21)
    n, d = 400, 6
    vecs = make_vectors(n, d, seed=2)
    # plant 4 exact duplicates of one row
    dup = [37, 120, 233, 301]
    vecs[dup] = vecs[17]
    s, t = _intervals(rng, n)
    # give the planted rows wide intervals so they are valid for the query
    s[[17] + dup] = 10.0
    t[[17] + dup] = 90.0
    idx = build_segmented_index(vecs, s, t, "overlap", cells_per_axis=2,
                                M=8, Z=32, K_p=4, quantize_int8=True)
    q = vecs[17].copy()
    ids, dist = idx.search(q[None], np.asarray([20.0]), np.asarray([80.0]),
                           k=8, beam=96, fetch_k=32)
    zero = ids[0][np.asarray(dist[0]) == 0.0]
    expect = np.sort(np.asarray([17] + dup))
    np.testing.assert_array_equal(zero, expect)
    # equal-distance block is sorted ascending by id (lexsort tie rule)
    assert np.all(np.diff(zero) > 0)


def test_all_invalid_query_returns_empty(seg_env):
    idx = seg_env["idx"]
    q = make_queries_vectors(3, seg_env["vecs"].shape[1], seed=99)
    # intervals past every datum: no valid object for any relation state
    sq = np.full(3, 1e9)
    tq = np.full(3, 2e9)
    ids, d, route = idx.search(q, sq, tq, k=5, return_route=True)
    assert not route.any()
    assert np.all(ids == -1)
    assert np.all(np.isinf(d))


# --- satellite: one compiled program across mixed segment counts --------------


def test_no_recompile_across_segment_mixes(seg_env):
    """Mixed routed-segment counts must reuse the SAME compiled executor and
    merge-fold programs (jit-cache idiom from test_planner.py). Distinct
    k/beam from every other test so the first search compiles exactly one
    new variant of each. ``scheduler=False`` pins the legacy per-segment
    loop — the parity oracle keeps its own no-recompile guarantee."""
    idx, qs = seg_env["idx"], seg_env["qs"]
    B = 8
    qv = qs.vectors[:B]

    exec0 = planned_exec_cache_size()
    fold0 = merge_fold_cache_size()
    # mix 1: normal queries (route to several segments each)
    idx.search(qv, qs.s_q[:B], qs.t_q[:B], k=7, beam=48, scheduler=False)
    exec1 = planned_exec_cache_size()
    fold1 = merge_fold_cache_size()
    assert exec1 - exec0 == 1, (exec0, exec1)
    assert fold1 - fold0 == 1, (fold0, fold1)

    # mix 2: narrow queries (tiny dominance rectangle -> few segments);
    # mix 3: maximal queries (route everywhere). Same shapes, no recompile.
    s, t = seg_env["s"], seg_env["t"]
    narrow_s = np.full(B, float(np.median(s)))
    narrow_t = narrow_s + 0.5
    wide_s = np.full(B, float(s.min()))
    wide_t = np.full(B, float(t.max()))
    _, _, r_narrow = idx.search(qv, narrow_s, narrow_t, k=7, beam=48,
                                return_route=True, scheduler=False)
    _, _, r_wide = idx.search(qv, wide_s, wide_t, k=7, beam=48,
                              return_route=True, scheduler=False)
    # the wide mix routes every (query, segment) pair; the narrow mix is a
    # (possibly strict) subset — both reuse the warm programs
    assert r_wide.all()
    assert r_wide.sum() >= r_narrow.sum()
    assert planned_exec_cache_size() == exec1
    assert merge_fold_cache_size() == fold1


# --- tentpole: worklist scheduler — one dispatch, bit-identical results -------


def test_worklist_single_dispatch_bit_parity(seg_env):
    """The scheduler must return byte-for-byte what the per-segment loop
    returns (ids AND distances, with and without the rerank tail) while
    issuing ONE device dispatch for the whole routed mix instead of one
    per routed segment."""
    idx, qs = seg_env["idx"], seg_env["qs"]
    for rerank in (False, True):
        d0 = dispatch_count()
        out_s = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                           rerank=rerank, return_route=True, scheduler=True)
        d1 = dispatch_count()
        out_l = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                           rerank=rerank, return_route=True, scheduler=False)
        d2 = dispatch_count()
        np.testing.assert_array_equal(out_s[0], out_l[0])
        np.testing.assert_array_equal(out_s[1], out_l[1])
        np.testing.assert_array_equal(out_s[2], out_l[2])
        route = out_s[2]
        n_routed = int(route.any(axis=0).sum())
        assert n_routed >= 2  # the mix is non-trivial
        assert d1 - d0 == 1, (d0, d1)
        assert d2 - d1 == n_routed, (d1, d2, n_routed)


@pytest.mark.parametrize("relname", RELATION_NAMES)
def test_worklist_bit_parity_all_relations(relname):
    """Scheduler vs loop parity under every relation mapping (distinct
    dominance-space shapes route distinct segment mixes)."""
    n, d = 700, 8
    vecs = make_vectors(n, d, seed=13)
    s, t = _intervals(np.random.default_rng(13), n)
    idx = build_segmented_index(
        vecs, s, t, relname, cells_per_axis=2, M=8, Z=32, K_p=4,
        quantize_int8=True,
    )
    qv = make_queries_vectors(12, d, seed=5)
    qs = ground_truth(
        generate_queries(qv, s, t, relname, 0.1, k=10, seed=9), vecs, s, t)
    a = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                   rerank=False, scheduler=True)
    b = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                   rerank=False, scheduler=False)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


@pytest.mark.parametrize("plan", ["graph", "wide", "brute"])
@pytest.mark.parametrize("fused", [True, False])
def test_worklist_plan_mode_parity(seg_env, plan, fused):
    """Forced plan modes (incl. the ragged brute-list path, which the
    scheduler pads to ONE worklist-wide power-of-two capacity) and both
    label layouts stay bit-identical to the loop."""
    idx, qs = seg_env["idx"], seg_env["qs"]
    a = idx.search(qs.vectors, qs.s_q, qs.t_q, k=6, beam=32, plan=plan,
                   fused=fused, rerank=False, scheduler=True)
    b = idx.search(qs.vectors, qs.s_q, qs.t_q, k=6, beam=32, plan=plan,
                   fused=fused, rerank=False, scheduler=False)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_worklist_bucket_no_recompile(seg_env):
    """Routed-mix changes land in a small closed set of quarter-octave
    worklist buckets: after warming each mix's bucket once, re-running
    EVERY mix adds zero compiled variants of ``worklist_exec_core``."""
    idx, qs = seg_env["idx"], seg_env["qs"]
    s, t = seg_env["s"], seg_env["t"]
    B = 8
    qv = qs.vectors[:B]
    narrow_s = np.full(B, float(np.median(s)))
    narrow_t = narrow_s + 0.5
    wide_s = np.full(B, float(s.min()))
    wide_t = np.full(B, float(t.max()))
    mixes = [
        (qs.s_q[:B], qs.t_q[:B]),   # normal: several segments per query
        (narrow_s, narrow_t),       # narrow: few (query, segment) pairs
        (wide_s, wide_t),           # maximal: every pair routed
    ]
    for sq, tq in mixes:            # warm each mix's bucket
        idx.search(qv, sq, tq, k=9, beam=40)
    warm = worklist_exec_cache_size()
    for sq, tq in mixes:
        idx.search(qv, sq, tq, k=9, beam=40)
    assert worklist_exec_cache_size() == warm


def test_worklist_capacity_buckets():
    # quarter-octave ladder: pow2 plus the 1.25/1.5/1.75 steps
    assert [worklist_capacity(w) for w in (0, 1, 7, 8, 9, 11, 39, 64, 65)] \
        == [8, 8, 8, 8, 10, 12, 40, 64, 80]
    for w in (1, 5, 8, 13, 39, 100, 1000):
        cap = worklist_capacity(w)
        assert cap >= max(w, 8)
        assert cap < 2 * max(w, 8)      # waste strictly under 2x
        assert cap <= 1.25 * max(w, 8) or cap == 8  # <= 25% padding
        # cap is pow2 or pow2 * {1.25, 1.5, 1.75}
        base = 1 << (cap.bit_length() - 1)
        assert cap * 4 % base == 0


def test_empty_worklist_no_dispatch(seg_env):
    """An all-invalid batch produces an empty worklist: the scheduler must
    return the padded empty result WITHOUT touching the device."""
    idx = seg_env["idx"]
    q = make_queries_vectors(4, seg_env["vecs"].shape[1], seed=77)
    sq = np.full(4, 1e9)
    tq = np.full(4, 2e9)
    d0 = dispatch_count()
    ids, d, st = idx.search(q, sq, tq, k=5, scheduler=True, stats=True)
    assert dispatch_count() == d0
    assert np.all(ids == -1)
    assert np.all(np.isinf(d))
    # zero stats, field-identical to the loop path's empty case
    _, _, st_l = idx.search(q, sq, tq, k=5, scheduler=False, stats=True)
    for name in st._fields:
        np.testing.assert_array_equal(
            getattr(st, name), getattr(st_l, name), err_msg=name)


def test_worklist_stats_parity(seg_env):
    """SearchStats out of the scheduler's one dispatch (scatter-added over
    the worklist) must equal the loop's ``combine_stats`` fold field by
    field — the counters are per-query trajectory sums, and the
    trajectory sets are identical."""
    idx, qs = seg_env["idx"], seg_env["qs"]
    # plan="graph" guarantees every routed pair actually traverses (the
    # auto planner may legally brute the whole batch, where counters are
    # all-zero by contract — that case is still compared, via "auto")
    for plan, check_nonzero in (("graph", True), ("auto", False)):
        *_, st_s = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                              plan=plan, scheduler=True, stats=True)
        *_, st_l = idx.search(qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                              plan=plan, scheduler=False, stats=True)
        if check_nonzero:
            assert int(np.sum(st_s.cand_total)) > 0
        for name in st_s._fields:
            np.testing.assert_array_equal(
                getattr(st_s, name), getattr(st_l, name), err_msg=name)


# --- satellite: byte accounting -----------------------------------------------


def test_nbytes_accounting_monolithic_and_segmented(seg_env):
    idx = seg_env["idx"]
    comp = idx.nbytes_by_component()
    assert sum(comp.values()) == idx.nbytes()
    assert comp["router"] == idx.grid.nbytes() > 0

    # packed labels: exactly 8 bytes/edge slot in every segment
    assert idx.packed
    for seg in idx.segments:
        dg = seg.dg
        assert dg.plabels is not None
        assert dg.plabels.nbytes == idx.node_capacity * idx.edge_capacity * 8

    # int8 residency: 1 byte/dim resident rows, f32 copies 4x larger
    assert comp["vec_q"] * 4 == comp["vectors"]
    assert comp["scales"] == comp["norms"]

    # monolithic DeviceGraph obeys the same sum rule
    vecs, s, t = seg_env["vecs"], seg_env["s"], seg_env["t"]
    g, _ = build_udg_batched(vecs[:300], s[:300], t[:300], "overlap",
                             M=8, Z=32, K_p=4)
    dg = export_device_graph(g, quantize_int8=True)
    assert sum(dg.nbytes_by_component().values()) == dg.nbytes()


# --- satellite: seed-sweep determinism ----------------------------------------


def test_segmented_build_and_search_deterministic():
    n, d = 800, 8
    vecs, s, t = make_dataset(n, d, seed=31)
    qv = make_queries_vectors(8, d, seed=4)
    sq, tq = _intervals(np.random.default_rng(6), 8)

    runs = []
    for _ in range(2):
        idx = build_segmented_index(vecs, s, t, "overlap",
                                    cells_per_axis=3, M=8, Z=32, K_p=4)
        ids, dist = idx.search(qv, sq, tq, k=10, beam=48)
        runs.append((idx, ids, dist))
    a, b = runs
    np.testing.assert_array_equal(a[1], b[1])
    np.testing.assert_array_equal(a[2], b[2])
    assert a[0].num_segments == b[0].num_segments
    for sa, sb in zip(a[0].segments, b[0].segments):
        np.testing.assert_array_equal(sa.ids, sb.ids)
        np.testing.assert_array_equal(np.asarray(sa.dg.nbr),
                                      np.asarray(sb.dg.nbr))
        np.testing.assert_array_equal(np.asarray(sa.dg.vec_q),
                                      np.asarray(sb.dg.vec_q))


# --- satellite: streaming segment-local epoch swap ----------------------------


def test_streaming_segment_local_epoch_swap():
    rng = np.random.default_rng(44)
    d = 6
    # construction-time space just seeds the grid; inserts may be off-grid
    s0, t0 = _intervals(rng, 300)
    rel = get_relation("overlap")
    space = DominanceSpace.from_intervals(rel, s0, t0)
    grid = SegmentGrid.from_space(space, 2)
    idx = SegmentedStreamingIndex(
        d, "overlap", grid,
        node_capacity=512, delta_capacity=128, edge_capacity=64,
        M=6, Z=24, K_p=4,
        policy=CompactionPolicy(max_delta_fraction=0.05, min_mutations=16),
        build_kwargs=dict(M=6, Z=24, K_p=4),
    )
    vecs = make_vectors(300, d, seed=8)
    idx.insert_batch(vecs, s0, t0)
    assert idx.live_count == 300
    # hot cells overflowed their delta and flush-compacted DURING insert;
    # cold cells must still be at epoch 0 — swaps are segment-local
    flushed = idx.epochs()
    assert any(e >= 1 for e in flushed)
    assert any(e == 0 for e in flushed)
    assert idx.swap_counts == flushed

    # now trip the policy in exactly one hot segment via deletes
    hot = int(np.argmax(flushed))
    victims = idx.subs[hot].live_ids()[:24]
    for e in victims:
        assert idx.delete(int(e))
    before = idx.epochs()
    reports = idx.maybe_compact()
    after = idx.epochs()
    assert hot in reports, (reports, before)
    for ci in range(idx.num_segments):
        if ci in reports:
            assert after[ci] == before[ci] + 1, ci
        else:
            # segment-local: untouched segments keep their epoch
            assert after[ci] == before[ci], ci
    assert idx.swap_counts == after

    # search parity vs brute oracle over live objects
    qv = make_queries_vectors(6, d, seed=12)
    sq, tq = _intervals(rng, 6)
    ids, dist = idx.search(qv, sq, tq, k=5, beam=48)
    # external id -> insertion order: ids were handed out round-robin per
    # cell, so recover (vec, s, t) via the per-sub id namespace
    ext_meta = {}
    cell = grid.assign_values(*rel.transform_data(s0, t0))
    counters = [0] * idx.num_segments
    for i in range(300):
        ci = int(cell[i])
        ext = ci + counters[ci] * idx.num_segments
        counters[ci] += 1
        ext_meta[ext] = i
    dead = {ext_meta[int(e)] for e in victims}
    for b in range(6):
        m = np.asarray(rel.valid_mask(s0, t0, sq[b], tq[b]))
        vids = np.array([i for i in np.flatnonzero(m) if i not in dead])
        for e in ids[b]:
            if e >= 0:
                assert ext_meta[int(e)] in vids, (b, e)
        if vids.size:
            dd = np.sum((vecs[vids] - qv[b]) ** 2, axis=1)
            best = vids[np.argmin(dd)]
            got = {ext_meta[int(e)] for e in ids[b] if e >= 0}
            assert best in got, b


# --- satellite: segment-local stack patch on streaming epoch swap -------------


def test_streaming_stack_patch_is_segment_local():
    """``on_epoch_swap`` must restage ONLY the swapped segment's slice of
    the flat device stack: every other part keeps the very same device
    buffers (object identity), and the flat concat is invalidated so the
    next read sees the new epoch."""
    rng = np.random.default_rng(44)
    d = 6
    s0, t0 = _intervals(rng, 300)
    rel = get_relation("overlap")
    space = DominanceSpace.from_intervals(rel, s0, t0)
    grid = SegmentGrid.from_space(space, 2)
    idx = SegmentedStreamingIndex(
        d, "overlap", grid,
        node_capacity=512, delta_capacity=128, edge_capacity=64,
        M=6, Z=24, K_p=4,
        policy=CompactionPolicy(max_delta_fraction=0.05, min_mutations=16),
        build_kwargs=dict(M=6, Z=24, K_p=4),
    )
    vecs = make_vectors(300, d, seed=8)
    idx.insert_batch(vecs, s0, t0)

    stack = idx.device_stack()
    assert stack.num_segments == idx.num_segments
    before = [stack.part(ci) for ci in range(stack.num_segments)]
    flat0 = stack.flat("nbr")  # materialize the concat cache

    # trip the policy in exactly one hot segment via deletes
    hot = int(np.argmax(idx.epochs()))
    for e in idx.subs[hot].live_ids()[:24]:
        assert idx.delete(int(e))
    reports = idx.maybe_compact()
    assert hot in reports

    after = [stack.part(ci) for ci in range(stack.num_segments)]
    for ci in range(stack.num_segments):
        for key in ("table", "nbr", "labels", "gids"):
            same = after[ci][key] is before[ci][key]
            if ci in reports:
                assert not same, (ci, key)
            else:
                assert same, (ci, key)
    # the flat concat restaged and reflects the swapped segment's new
    # live-id table (the deleted rows left the gids slice)
    flat1 = stack.flat("nbr")
    assert flat1 is not flat0
    ncap = stack.node_capacity
    gids = np.asarray(stack.flat("gids"))
    live = set(idx.subs[hot].live_ids().tolist())
    seg_gids = gids[hot * ncap : (hot + 1) * ncap]
    assert set(seg_gids[seg_gids >= 0].tolist()) == live


# --- satellite: sharded serving device bundle derives from the stack ----------


def test_sharded_device_bundle_reuses_segment_stack():
    """``segments_to_sharded_index`` primes the sharded device cache from
    the scheduler's flat ``SegmentStack`` (un-offsetting the adjacency on
    device) — the derived bundle must equal the stacked host arrays
    exactly."""
    vecs, s, t = make_dataset(600, 8, seed=17)
    idx = build_segmented_index(
        vecs, s, t, "overlap", cells_per_axis=2, M=8, Z=32, K_p=4,
        quantize_int8=False,
    )
    from repro.serve.distributed import segments_to_sharded_index

    sharded, id_map = segments_to_sharded_index(idx)
    assert sharded._cache is not None  # primed at build, not first use
    dev = sharded.device()
    np.testing.assert_array_equal(np.asarray(dev["nbr"]), sharded.nbr)
    np.testing.assert_array_equal(np.asarray(dev["labels"]), sharded.labels)
    np.testing.assert_array_equal(np.asarray(dev["vectors"]), sharded.vectors)
    # id_map agrees with the stack's device-resident global-id table
    gids = np.asarray(idx.device_stack().flat("gids")).reshape(
        sharded.num_shards, sharded.n_local)
    np.testing.assert_array_equal(gids, id_map.astype(np.int32))


# --- satellite: segment-sharded serving (multi-host-device, subprocess) -------


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_segments_sharded_across_devices():
    out = _run(
        """
import numpy as np
from repro.core import get_relation
from repro.data import make_dataset, make_queries_vectors, generate_queries, ground_truth, recall_at_k
from repro.launch.mesh import make_host_mesh
from repro.scale import build_segmented_index
from repro.serve.distributed import segments_to_sharded_index, serve_batch

vecs, s, t = make_dataset(1024, 8, seed=0)
idx = build_segmented_index(vecs, s, t, "overlap", cells_per_axis=2, M=8, Z=32, K_p=4, quantize_int8=False)
sh, id_map = segments_to_sharded_index(idx)
assert sh.num_shards == idx.num_segments == 4, sh.num_shards
mesh = make_host_mesh(model_parallel=sh.num_shards)
qv = make_queries_vectors(12, 8, seed=1)
qs = ground_truth(generate_queries(qv, s, t, "overlap", 0.08, k=10, seed=2), vecs, s, t)
ids, d = serve_batch(sh, mesh, qs.vectors, qs.s_q, qs.t_q, k=10, beam=64, id_map=id_map)
rel = get_relation("overlap")
for i in range(qs.nq):
    m = rel.valid_mask(s, t, qs.s_q[i], qs.t_q[i])
    assert all(m[j] for j in ids[i] if j >= 0), i
r = recall_at_k(np.asarray(ids), qs)
assert r >= 0.9, r
print("segment-sharded recall", round(r, 3))
""")
    assert "segment-sharded recall" in out
