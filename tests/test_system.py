"""End-to-end behaviour of the whole system (paper pipeline + LM substrate)."""
import numpy as np
import pytest

from repro.core import build_index, search_query
from repro.data import (
    generate_queries,
    ground_truth,
    make_dataset,
    make_queries_vectors,
    recall_at_k,
)

from conftest import pad_ids


@pytest.mark.parametrize("relation,distribution,sigma", [
    ("containment", "uniform", 0.01),
    ("overlap", "uniform", 0.01),
    ("containment", "clustered", 0.1),
    ("overlap", "hollow", 0.1),
    ("both_after", "uniform", 0.1),
    ("both_before", "skewed", 0.1),
    ("query_within_data", "uncapped", 0.01),
])
def test_end_to_end_udg_pipeline(relation, distribution, sigma):
    """The paper's full pipeline: data -> build -> canonicalize -> search,
    recall@10 >= 0.9 for every supported relation under varied metadata."""
    vecs, s, t = make_dataset(1200, 16, distribution=distribution, seed=20)
    qv = make_queries_vectors(16, 16, seed=21)
    g, et, rep = build_index(vecs, s, t, relation, M=10, Z=48, K_p=8)
    assert rep.seconds < 120
    qs = ground_truth(generate_queries(qv, s, t, relation, sigma, k=10, seed=22),
                      vecs, s, t)
    res = np.stack([
        pad_ids(search_query(g, qs.vectors[i], qs.s_q[i], qs.t_q[i], 10, 64, et)[0], 10)
        for i in range(qs.nq)
    ])
    assert recall_at_k(res, qs) >= 0.9, (relation, distribution, sigma)


def test_one_index_many_relations_share_machinery():
    """Containment and overlap indexes on the same data reuse identical
    construction/search code paths (relation-independence, paper §IV)."""
    vecs, s, t = make_dataset(600, 12, seed=23)
    qv = make_queries_vectors(8, 12, seed=24)
    for relation in ("containment", "overlap"):
        g, et, _ = build_index(vecs, s, t, relation, M=8, Z=32)
        qs = ground_truth(generate_queries(qv, s, t, relation, 0.05, k=5, seed=25),
                          vecs, s, t)
        res = np.stack([
            pad_ids(search_query(g, qs.vectors[i], qs.s_q[i], qs.t_q[i], 5, 48, et)[0], 5)
            for i in range(qs.nq)
        ])
        assert recall_at_k(res, qs) >= 0.9, relation


def test_tiny_lm_training_loss_decreases():
    """The training substrate end-to-end: loss drops on a memorizable task."""
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.models.steps import make_train_step
    from repro.train import adamw

    cfg = get_config("llama3.2-1b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)
    batch = {"tokens": tokens, "labels": np.roll(tokens, -1, 1)}
    first = last = None
    for i in range(25):
        params, opt_state, m = step(params, opt_state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.7, (first, last)
