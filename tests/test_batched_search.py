"""Batched jittable search: parity with the host reference + edge cases."""
import numpy as np
import pytest

from repro.core import build_index, get_relation
from repro.data import generate_queries, ground_truth, make_dataset, recall_at_k
from repro.search import batched_udg_search, export_device_graph, prepare_states


@pytest.fixture(scope="module")
def setup(small_dataset, query_vectors):
    vecs, s, t = small_dataset
    g, et, _ = build_index(vecs, s, t, "overlap", M=10, Z=48, K_p=8)
    dg = export_device_graph(g, et)
    return vecs, s, t, g, dg


@pytest.mark.parametrize("sigma", [0.01, 0.1])
def test_batched_recall_and_validity(setup, query_vectors, sigma):
    vecs, s, t, g, dg = setup
    qs = ground_truth(
        generate_queries(query_vectors, s, t, "overlap", sigma, k=10, seed=8),
        vecs, s, t,
    )
    ids, dists = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                    k=10, beam=64, use_ref=True)
    rel = get_relation("overlap")
    for i in range(qs.nq):
        mask = rel.valid_mask(s, t, qs.s_q[i], qs.t_q[i])
        for j in ids[i]:
            if j >= 0:
                assert mask[j]
    assert recall_at_k(ids, qs) >= 0.95


def test_batched_with_pallas_kernel_matches_ref_path(setup, query_vectors):
    vecs, s, t, g, dg = setup
    qs = generate_queries(query_vectors[:6], s, t, "overlap", 0.05, k=5, seed=9)
    a, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q, k=5, beam=32,
                              use_ref=True)
    b, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q, k=5, beam=32,
                              use_ref=False)  # interpret-mode Pallas
    np.testing.assert_array_equal(a, b)


def test_empty_and_sentinel_queries(setup):
    vecs, s, t, g, dg = setup
    q = vecs[:3]
    # sentinel row: s_q > t_q -> no valid objects -> all -1
    s_q = np.array([s.min(), 50.0, 10.0])
    t_q = np.array([t.max(), 40.0, -5.0])  # rows 1,2 invalid intervals
    states, ep = prepare_states(dg, s_q, t_q)
    assert ep[0] >= 0
    ids, dists = batched_udg_search(dg, q, s_q, t_q, k=5, beam=16, use_ref=True)
    assert np.all(ids[2] == -1)


def test_prepare_states_matches_host_canonicalization(setup):
    vecs, s, t, g, dg = setup
    rng = np.random.default_rng(1)
    s_q = rng.uniform(s.min(), s.max(), 50)
    t_q = s_q + rng.uniform(0, (t - s).max() * 3, 50)
    states, ep = prepare_states(dg, s_q, t_q)
    for i in range(50):
        st = g.canonical_rank_state(float(s_q[i]), float(t_q[i]))
        if st is None:
            assert ep[i] == -1
        else:
            assert tuple(states[i]) == st


def test_device_graph_export_consistency(setup):
    vecs, s, t, g, dg = setup
    assert dg.nbr.shape[0] == g.n
    for u in (0, 5, 100):
        nbr, l, r, b, e = g.tuples(u)
        k = nbr.shape[0]
        np.testing.assert_array_equal(dg.nbr[u, :k], nbr)
        assert np.all(dg.nbr[u, k:] == -1)
        np.testing.assert_array_equal(dg.labels[u, :k, 0], l)
        np.testing.assert_array_equal(dg.labels[u, :k, 3], e)


def test_int8_search_path_recall(setup, query_vectors):
    """§Perf U3: int8-quantized database vectors keep full recall."""
    import jax.numpy as jnp
    from repro.data import generate_queries, ground_truth, recall_at_k
    from repro.kernels.int8dist import quantize_int8
    from repro.search.batched import _batched_search_core

    vecs, s, t, g, dg = setup
    qs = ground_truth(
        generate_queries(query_vectors, s, t, "overlap", 0.05, k=10, seed=33),
        vecs, s, t,
    )
    states, ep = prepare_states(dg, qs.s_q, qs.t_q)
    vq, sc = quantize_int8(jnp.asarray(dg.vectors))
    ids, _ = _batched_search_core(
        vq, jnp.asarray(dg.nbr), jnp.asarray(dg.labels),
        jnp.asarray(qs.vectors), jnp.asarray(states), jnp.asarray(ep),
        k=10, beam=64, max_iters=128, use_ref=True, scales=sc,
    )
    assert recall_at_k(np.asarray(ids), qs) >= 0.95
