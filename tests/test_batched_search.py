"""Batched jittable search: parity with the host reference + edge cases."""
import numpy as np
import pytest

from repro.core import build_index, get_relation
from repro.data import generate_queries, ground_truth, make_dataset, recall_at_k
from repro.search import batched_udg_search, export_device_graph, prepare_states


@pytest.fixture(scope="module")
def setup(small_dataset, query_vectors):
    vecs, s, t = small_dataset
    g, et, _ = build_index(vecs, s, t, "overlap", M=10, Z=48, K_p=8)
    dg = export_device_graph(g, et)
    return vecs, s, t, g, dg


@pytest.mark.parametrize("sigma", [0.01, 0.1])
def test_batched_recall_and_validity(setup, query_vectors, sigma):
    vecs, s, t, g, dg = setup
    qs = ground_truth(
        generate_queries(query_vectors, s, t, "overlap", sigma, k=10, seed=8),
        vecs, s, t,
    )
    ids, dists = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                    k=10, beam=64, use_ref=True)
    rel = get_relation("overlap")
    for i in range(qs.nq):
        mask = rel.valid_mask(s, t, qs.s_q[i], qs.t_q[i])
        for j in ids[i]:
            if j >= 0:
                assert mask[j]
    assert recall_at_k(ids, qs) >= 0.95


def test_batched_with_pallas_kernel_matches_ref_path(setup, query_vectors):
    vecs, s, t, g, dg = setup
    qs = generate_queries(query_vectors[:6], s, t, "overlap", 0.05, k=5, seed=9)
    a, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q, k=5, beam=32,
                              use_ref=True)
    b, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q, k=5, beam=32,
                              use_ref=False)  # interpret-mode Pallas
    np.testing.assert_array_equal(a, b)


@pytest.fixture(scope="module")
def setup_containment(small_dataset):
    vecs, s, t = small_dataset
    g, et, _ = build_index(vecs, s, t, "containment", M=10, Z=48, K_p=8)
    return vecs, s, t, export_device_graph(g, et)


@pytest.mark.parametrize("relation", ["overlap", "containment"])
def test_fused_path_parity_and_recall(setup, setup_containment, query_vectors,
                                      relation):
    """The gather-fused loop (in-kernel HBM gather, cached norms, bit-packed
    visited; n=1500 exercises the bitmap tail word) returns the same ids as
    the unfused baseline and the pallas kernel matches its jnp oracle
    bit-for-bit, on both workload relations."""
    if relation == "overlap":
        vecs, s, t, g, dg = setup
    else:
        vecs, s, t, dg = setup_containment
    qs = ground_truth(
        generate_queries(query_vectors, s, t, relation, 0.1, k=10, seed=21),
        vecs, s, t,
    )
    unfused, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                    k=10, beam=64, use_ref=True, fused=False)
    fused_ref, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                      k=10, beam=64, use_ref=True, fused=True)
    fused_pl, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                     k=10, beam=64, use_ref=False, fused=True)
    np.testing.assert_array_equal(fused_ref, fused_pl)
    assert recall_at_k(fused_ref, qs) == recall_at_k(unfused, qs)
    assert recall_at_k(fused_ref, qs) >= 0.95


def test_multi_expand_recall(setup, query_vectors):
    """expand=M>1 pops the best M unexpanded beam entries per iteration —
    fewer while-loop trips, same quality."""
    vecs, s, t, g, dg = setup
    qs = ground_truth(
        generate_queries(query_vectors, s, t, "overlap", 0.1, k=10, seed=22),
        vecs, s, t,
    )
    base, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                 k=10, beam=64, use_ref=True)
    for m in (2, 4):
        ids, _ = batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q,
                                    k=10, beam=64, use_ref=True, expand=m)
        assert recall_at_k(ids, qs) >= recall_at_k(base, qs) - 1e-9
    with pytest.raises(ValueError):
        batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                           use_ref=True, fused=False, expand=2)
    for bad in (0, -1, 65):   # out of [1, beam]
        with pytest.raises(ValueError):
            batched_udg_search(dg, qs.vectors, qs.s_q, qs.t_q, k=10, beam=64,
                               use_ref=True, expand=bad)


def test_int8_storage_end_to_end(setup, query_vectors):
    """quantize_int8 export carries vec_q/scales/dequantized norms and the
    public entry point serves from them (satellite: int8 actually reachable)."""
    vecs, s, t, g, dg = setup
    dg8 = export_device_graph(g, None, quantize_int8=True)
    assert dg8.vec_q is not None and dg8.vec_q.dtype == np.int8
    assert dg8.scales is not None and dg8.norms is not None
    qs = ground_truth(
        generate_queries(query_vectors, s, t, "overlap", 0.05, k=10, seed=33),
        vecs, s, t,
    )
    a, _ = batched_udg_search(dg8, qs.vectors, qs.s_q, qs.t_q,
                              k=10, beam=64, use_ref=True)
    b, _ = batched_udg_search(dg8, qs.vectors, qs.s_q, qs.t_q,
                              k=10, beam=64, use_ref=False)
    np.testing.assert_array_equal(a, b)
    assert recall_at_k(a, qs) >= 0.95


def test_empty_and_sentinel_queries(setup):
    vecs, s, t, g, dg = setup
    q = vecs[:3]
    # sentinel row: s_q > t_q -> no valid objects -> all -1
    s_q = np.array([s.min(), 50.0, 10.0])
    t_q = np.array([t.max(), 40.0, -5.0])  # rows 1,2 invalid intervals
    states, ep = prepare_states(dg, s_q, t_q)
    assert ep[0] >= 0
    ids, dists = batched_udg_search(dg, q, s_q, t_q, k=5, beam=16, use_ref=True)
    assert np.all(ids[2] == -1)


def test_prepare_states_matches_host_canonicalization(setup):
    vecs, s, t, g, dg = setup
    rng = np.random.default_rng(1)
    s_q = rng.uniform(s.min(), s.max(), 50)
    t_q = s_q + rng.uniform(0, (t - s).max() * 3, 50)
    states, ep = prepare_states(dg, s_q, t_q)
    for i in range(50):
        st = g.canonical_rank_state(float(s_q[i]), float(t_q[i]))
        if st is None:
            assert ep[i] == -1
        else:
            assert tuple(states[i]) == st


def test_device_graph_export_consistency(setup):
    vecs, s, t, g, dg = setup
    assert dg.nbr.shape[0] == g.n
    # default export bit-packs the rank rectangles (grid fits 16 bits);
    # labels_i32() is the unpacked view the parity-oracle paths use
    assert dg.plabels is not None and dg.plabels.dtype == np.uint32
    assert dg.plabels.shape == (g.n, dg.max_degree, 2)
    labels = dg.labels_i32()
    for u in (0, 5, 100):
        nbr, l, r, b, e = g.tuples(u)
        k = nbr.shape[0]
        np.testing.assert_array_equal(dg.nbr[u, :k], nbr)
        assert np.all(dg.nbr[u, k:] == -1)
        np.testing.assert_array_equal(labels[u, :k, 0], l)
        np.testing.assert_array_equal(labels[u, :k, 3], e)


def test_int8_search_path_recall(setup, query_vectors):
    """§Perf U3: int8-quantized database vectors keep full recall."""
    import jax.numpy as jnp
    from repro.data import generate_queries, ground_truth, recall_at_k
    from repro.kernels.int8dist import quantize_int8
    from repro.search.batched import _batched_search_core

    vecs, s, t, g, dg = setup
    qs = ground_truth(
        generate_queries(query_vectors, s, t, "overlap", 0.05, k=10, seed=33),
        vecs, s, t,
    )
    states, ep = prepare_states(dg, qs.s_q, qs.t_q)
    vq, sc = quantize_int8(jnp.asarray(dg.vectors))
    ids, _ = _batched_search_core(
        vq, jnp.asarray(dg.nbr), jnp.asarray(dg.labels_i32()),
        jnp.asarray(qs.vectors), jnp.asarray(states), jnp.asarray(ep),
        k=10, beam=64, max_iters=128, use_ref=True, scales=sc,
    )
    assert recall_at_k(np.asarray(ids), qs) >= 0.95
