"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs pure-jnp
oracles, across shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("bq,bc,d", [
    (1, 1, 4), (7, 33, 16), (128, 128, 64), (37, 215, 70), (130, 50, 200),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_l2dist_matches_ref(bq, bc, d, dtype):
    q = _arr((bq, d), dtype)
    c = _arr((bc, d), dtype)
    got = ops.l2dist(q, c)
    want = ref.l2dist_ref(q, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_l2dist_is_true_squared_distance():
    q = _arr((5, 12))
    c = _arr((9, 12))
    got = np.asarray(ops.l2dist(q, c))
    brute = np.sum(
        (np.asarray(q)[:, None, :] - np.asarray(c)[None, :, :]) ** 2, axis=-1
    )
    np.testing.assert_allclose(got, brute, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,e,d", [(1, 1, 4), (3, 17, 8), (8, 128, 32), (5, 200, 64)])
def test_filter_dist_matches_ref(b, e, d):
    q = _arr((b, d))
    cand = _arr((b, e, d))
    labels = jnp.asarray(RNG.integers(0, 12, size=(b, e, 4)).astype(np.int32))
    state = jnp.asarray(RNG.integers(0, 12, size=(b, 2)).astype(np.int32))
    ids = jnp.asarray(RNG.integers(-1, 40, size=(b, e)).astype(np.int32))
    got = np.asarray(ops.filter_dist(q, cand, labels, state, ids))
    want = np.asarray(ref.filter_dist_ref(q, cand, labels, state, ids))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_label_semantics():
    """a in [l, r] and c in [b, e] — closed on both ends (paper §IV-A)."""
    q = jnp.zeros((1, 4))
    cand = jnp.ones((1, 3, 4))
    #               active       a==r boundary   b > c (inactive)
    labels = jnp.asarray([[[0, 5, 0, 5], [2, 2, 0, 5], [0, 5, 3, 5]]], dtype=jnp.int32)
    state = jnp.asarray([[2, 2]], dtype=jnp.int32)
    ids = jnp.asarray([[0, 1, 2]], dtype=jnp.int32)
    out = np.asarray(ops.filter_dist(q, cand, labels, state, ids))
    assert np.isfinite(out[0, 0]) and np.isfinite(out[0, 1])
    assert np.isinf(out[0, 2])


@pytest.mark.parametrize("bq,bc,d", [(4, 9, 8), (65, 200, 48)])
def test_int8dist_matches_ref_and_f32(bq, bc, d):
    q = _arr((bq, d))
    c = _arr((bc, d))
    cq, cs = ops.quantize_int8(c)
    got = np.asarray(ops.int8_l2dist(q, cq, cs))
    want = np.asarray(ref.int8_l2dist_ref(q, cq, cs))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # quantization error vs exact f32 distances stays small & relative
    exact = np.asarray(ref.l2dist_ref(q, c))
    rel = np.abs(got - exact) / np.maximum(exact, 1e-3)
    assert np.median(rel) < 0.05


def test_quantize_int8_bounds():
    v = _arr((20, 16))
    q, scale = ops.quantize_int8(v)
    assert q.dtype == jnp.int8
    recon = np.asarray(q, dtype=np.float32) * np.asarray(scale)[:, None]
    err = np.max(np.abs(recon - np.asarray(v)))
    assert err <= np.max(np.abs(np.asarray(v))) / 127.0 * 0.51 + 1e-6
