"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs pure-jnp
oracles, across shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("bq,bc,d", [
    (1, 1, 4), (7, 33, 16), (128, 128, 64), (37, 215, 70), (130, 50, 200),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_l2dist_matches_ref(bq, bc, d, dtype):
    q = _arr((bq, d), dtype)
    c = _arr((bc, d), dtype)
    got = ops.l2dist(q, c)
    want = ref.l2dist_ref(q, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_l2dist_is_true_squared_distance():
    q = _arr((5, 12))
    c = _arr((9, 12))
    got = np.asarray(ops.l2dist(q, c))
    brute = np.sum(
        (np.asarray(q)[:, None, :] - np.asarray(c)[None, :, :]) ** 2, axis=-1
    )
    np.testing.assert_allclose(got, brute, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,e,d", [(1, 1, 4), (3, 17, 8), (8, 128, 32), (5, 200, 64)])
def test_filter_dist_matches_ref(b, e, d):
    q = _arr((b, d))
    cand = _arr((b, e, d))
    labels = jnp.asarray(RNG.integers(0, 12, size=(b, e, 4)).astype(np.int32))
    state = jnp.asarray(RNG.integers(0, 12, size=(b, 2)).astype(np.int32))
    ids = jnp.asarray(RNG.integers(-1, 40, size=(b, e)).astype(np.int32))
    got = np.asarray(ops.filter_dist(q, cand, labels, state, ids))
    want = np.asarray(ref.filter_dist_ref(q, cand, labels, state, ids))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_label_semantics():
    """a in [l, r] and c in [b, e] — closed on both ends (paper §IV-A)."""
    q = jnp.zeros((1, 4))
    cand = jnp.ones((1, 3, 4))
    #               active       a==r boundary   b > c (inactive)
    labels = jnp.asarray([[[0, 5, 0, 5], [2, 2, 0, 5], [0, 5, 3, 5]]], dtype=jnp.int32)
    state = jnp.asarray([[2, 2]], dtype=jnp.int32)
    ids = jnp.asarray([[0, 1, 2]], dtype=jnp.int32)
    out = np.asarray(ops.filter_dist(q, cand, labels, state, ids))
    assert np.isfinite(out[0, 0]) and np.isfinite(out[0, 1])
    assert np.isinf(out[0, 2])


def _gather_case(n, b, c, d, seed=0):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    norms = jnp.sum(table * table, axis=1)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, n, size=(b, c)).astype(np.int32))
    labels = jnp.asarray(rng.integers(0, 12, size=(b, c, 4)).astype(np.int32))
    state = jnp.asarray(rng.integers(0, 12, size=(b, 2)).astype(np.int32))
    W = (n + 31) // 32
    vis = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(b, W), dtype=np.uint64).astype(np.uint32)
    )
    return table, norms, q, ids, labels, state, vis


@pytest.mark.parametrize("n,b,c,d", [
    (33, 1, 5, 4),        # B=1, n not a multiple of 32 (bitmap tail word)
    (100, 3, 24, 7),      # odd D
    (200, 4, 130, 16),    # C not a multiple of the tile
    (513, 2, 260, 32),    # multi-tile with n % 32 != 0
])
def test_filter_dist_gather_matches_ref(n, b, c, d):
    table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d)
    got = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis)
    )
    want = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis,
                               use_ref=True)
    )
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_gather_small_tile_boundaries():
    """Direct kernel call with te=8: 3 tiles + padded tail exercises the
    double-buffered DMA pipeline across tile steps."""
    from repro.kernels.filter_dist import filter_dist_gather_pallas

    n, b, c, d = 75, 2, 20, 12
    table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed=5)
    safe = jnp.clip(ids, 0, n - 1)
    g_norms = norms[safe]
    g_words = jnp.take_along_axis(vis, safe >> 5, axis=1)
    g_scales = jnp.ones_like(g_norms)
    got = np.asarray(filter_dist_gather_pallas(
        table, q, ids, labels, state, g_norms, g_words, g_scales,
        interpret=True, te=8,
    ))
    want = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis,
                               use_ref=True)
    )
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_gather_all_invalid_tile():
    """A tile of nothing but -1 padding must come back all +inf (and the
    row-0 fetches it degenerates to must not affect other tiles)."""
    n, b, c, d = 64, 2, 16, 8
    table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed=7)
    ids = jnp.full((b, c), -1, jnp.int32)
    got = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis)
    )
    assert np.all(np.isinf(got))


def test_filter_dist_gather_visited_bitmap_semantics():
    """Bit i>>5 : i&31 set => candidate i suppressed; includes the tail word
    of an n that is not a multiple of 32."""
    n, d = 45, 8            # words: [32, 13-bit tail]
    table = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    norms = jnp.sum(table * table, axis=1)
    q = jnp.zeros((1, d), jnp.float32)
    ids = jnp.asarray([[3, 31, 32, 44]], dtype=jnp.int32)
    labels = jnp.zeros((1, 4, 4), jnp.int32)
    labels = labels.at[..., 1].set(10).at[..., 3].set(10)   # wide-open rects
    state = jnp.asarray([[5, 5]], jnp.int32)
    vis = np.zeros((1, 2), np.uint32)
    vis[0, 0] = (np.uint32(1) << 31) | np.uint32(1 << 3)    # marks 31 and 3
    vis[0, 1] = np.uint32(1 << (44 - 32))                   # marks 44 (tail)
    for use_ref in (True, False):
        out = np.asarray(ops.filter_dist_gather(
            table, norms, q, ids, labels, state, jnp.asarray(vis),
            use_ref=use_ref,
        ))
        assert np.isinf(out[0, 0]) and np.isinf(out[0, 1])   # 3, 31 visited
        assert np.isfinite(out[0, 2])                        # 32 clear
        assert np.isinf(out[0, 3])                           # 44 visited


@pytest.mark.slow
def test_filter_dist_gather_exhaustive_sweep():
    """Randomized shape sweep (marked slow): every combination of B=1/odd
    D/tile-straddling C/bitmap-tail n across several seeds."""
    cases = [
        (n, b, c, d, seed)
        for n in (31, 64, 257)
        for b in (1, 5)
        for c in (3, 129)
        for d in (6, 32)
        for seed in (0, 1)
    ]
    for n, b, c, d, seed in cases:
        table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed)
        got = np.asarray(
            ops.filter_dist_gather(table, norms, q, ids, labels, state, vis)
        )
        want = np.asarray(
            ops.filter_dist_gather(table, norms, q, ids, labels, state, vis,
                                   use_ref=True)
        )
        fin = np.isfinite(want)
        np.testing.assert_array_equal(np.isfinite(got), fin, err_msg=str((n, b, c, d)))
        np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4,
                                   err_msg=str((n, b, c, d)))


def test_filter_dist_gather_int8_scales():
    n, b, c, d = 90, 3, 33, 16
    table, _, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed=9)
    tq, sc = ops.quantize_int8(table)
    deq = tq.astype(jnp.float32) * sc[:, None]
    norms = jnp.sum(deq * deq, axis=1)
    got = np.asarray(ops.filter_dist_gather(
        tq, norms, q, ids, labels, state, vis, scales=sc,
    ))
    want = np.asarray(ops.filter_dist_gather(
        tq, norms, q, ids, labels, state, vis, scales=sc, use_ref=True,
    ))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-3, atol=1e-3)


def _packed_case(n, b, m, e, d, seed=0, rank_hi=12):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    norms = jnp.sum(table * table, axis=1)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    cur = jnp.asarray(rng.integers(0, n, size=(b, m)).astype(np.int32))
    cand = jnp.asarray(rng.integers(-1, n, size=(b, m * e)).astype(np.int32))
    lo = rng.integers(0, rank_hi, size=(n, e, 2)).astype(np.uint32)
    hi = rng.integers(0, rank_hi, size=(n, e, 2)).astype(np.uint32)
    plabels = jnp.asarray(lo | (hi << 16))
    state = jnp.asarray(rng.integers(0, rank_hi, size=(b, 2)).astype(np.int32))
    W = (n + 31) // 32
    vis = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(b, W), dtype=np.uint64).astype(np.uint32)
    )
    return table, plabels, norms, q, cur, cand, state, vis


@pytest.mark.parametrize("n,b,m,e,d", [
    (33, 1, 1, 5, 4),       # B=1, bitmap tail word
    (100, 3, 2, 12, 7),     # odd D, multi-expand label rows
    (200, 4, 1, 130, 16),   # M*E not a multiple of the tile
    (257, 2, 4, 65, 32),    # wide multi-expand straddling tiles
])
def test_filter_dist_gather_packed_matches_ref(n, b, m, e, d):
    """The packed superkernel (in-kernel label-row DMA + mask-and-shift
    dominance test) matches its jnp oracle across tile/expand shapes."""
    args = _packed_case(n, b, m, e, d)
    got = np.asarray(ops.filter_dist_gather_packed(*args))
    want = np.asarray(ops.filter_dist_gather_packed(*args, use_ref=True))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_gather_packed_matches_int32_kernel():
    """Packed words and the int32 rectangles encode the same test: the
    packed superkernel agrees with the int32 gather kernel given the
    unpacked layout of the same labels."""
    from repro.search.device_graph import unpack_labels

    n, b, m, e, d = 90, 3, 2, 11, 8
    table, plabels, norms, q, cur, cand, state, vis = _packed_case(
        n, b, m, e, d, seed=3)
    got = np.asarray(ops.filter_dist_gather_packed(
        table, plabels, norms, q, cur, cand, state, vis))
    lab4 = jnp.asarray(unpack_labels(np.asarray(plabels)))
    lab_g = lab4[jnp.clip(cur, 0, n - 1)].reshape(b, m * e, 4)
    want = np.asarray(ops.filter_dist_gather(
        table, norms, q, cand, lab_g, state, vis))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_packed_label_semantics_boundaries():
    """Closed rectangle bounds survive the 16-bit packing: a == r and the
    b > c inactive case behave exactly as the int32 label test."""
    from repro.search.device_graph import pack_labels

    n, d = 8, 4
    table = jnp.zeros((n, d), jnp.float32)
    norms = jnp.zeros((n,), jnp.float32)
    q = jnp.zeros((1, d), jnp.float32)
    #            active        a==r boundary   b > c (inactive)
    lab4 = np.array([[[0, 5, 0, 5], [2, 2, 0, 5], [0, 5, 3, 5]]], np.int32)
    plabels = jnp.asarray(np.broadcast_to(pack_labels(lab4[0])[None], (n, 3, 2)))
    cur = jnp.zeros((1, 1), jnp.int32)
    cand = jnp.asarray([[0, 1, 2]], dtype=jnp.int32)
    state = jnp.asarray([[2, 2]], jnp.int32)
    vis = jnp.zeros((1, 1), jnp.uint32)
    for use_ref in (True, False):
        out = np.asarray(ops.filter_dist_gather_packed(
            table, plabels, norms, q, cur, cand, state, vis, use_ref=use_ref))
        assert np.isfinite(out[0, 0]) and np.isfinite(out[0, 1])
        assert np.isinf(out[0, 2])


def _merge_case(b, l, c, n, seed=0, tie_heavy=False, all_inf=False):
    rng = np.random.default_rng(seed)
    beam_d = np.sort(rng.normal(size=(b, l)).astype(np.float32) ** 2, axis=1)
    ninf = int(rng.integers(0, max(l // 2, 1)))
    if ninf:
        beam_d[:, l - ninf:] = np.inf
    beam_ids = rng.integers(-1, n, size=(b, l)).astype(np.int32)
    beam_ids[~np.isfinite(beam_d)] = -1
    beam_exp = rng.random((b, l)) < 0.5
    if tie_heavy:
        # few distinct distances + few distinct ids: every tie-break and
        # duplicate rule is exercised
        cand_d = rng.integers(0, 4, size=(b, c)).astype(np.float32)
        cand_ids = rng.integers(0, min(8, n), size=(b, c)).astype(np.int32)
        beam_d = np.sort(
            rng.integers(0, 4, size=(b, l)).astype(np.float32), axis=1)
    else:
        cand_d = rng.normal(size=(b, c)).astype(np.float32) ** 2
        cand_ids = rng.integers(-1, n, size=(b, c)).astype(np.int32)
    cand_d[rng.random((b, c)) < 0.3] = np.inf
    if all_inf:
        cand_d[:] = np.inf
        cand_ids[:] = -1
    return tuple(map(jnp.asarray,
                     (beam_d, beam_ids, beam_exp, cand_d, cand_ids)))


@pytest.mark.parametrize("b,l,c,n,tie,all_inf", [
    (3, 64, 88, 4000, False, False),   # bench shape
    (2, 48, 17, 100, False, False),    # L and C not powers of two
    (1, 7, 3, 10, True, False),        # tiny, tie-heavy
    (2, 32, 40, 40, True, False),      # heavy duplicate ids + tied dists
    (2, 16, 8, 50, False, True),       # all-inf candidate set
    (2, 96, 352, 65000, False, False), # wide-beam / multi-expand scale
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_beam_merge_matches_stable_sort_oracle(b, l, c, n, tie, all_inf, seed):
    """Both beam_merge implementations — the jnp top_k path and the Pallas
    bitonic sort+merge network (interpret) — are bitwise equal to the
    stable lax.sort oracle, including exact distance ties, duplicate ids,
    all-inf candidates, and non-power-of-two L / M·E."""
    from repro.kernels.beam_merge import beam_merge_jnp, beam_merge_pallas

    case = _merge_case(b, l, c, n, seed, tie, all_inf)
    want = ref.beam_merge_ref(*case, n=n)
    got_jnp = beam_merge_jnp(*case, n=n)
    got_pl = beam_merge_pallas(*case, n=n, interpret=True)
    names = ("ids", "d", "exp", "keep")
    for g, w, nm in zip(got_jnp, want, names):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"jnp {nm}")
    for g, w, nm in zip(got_pl, want, names):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"pallas {nm}")


def test_beam_merge_dedup_keeps_first_and_marks_bits():
    """Duplicate ids: exactly the first finite occurrence survives (keep
    bit set), later copies are suppressed, and the merged beam holds the
    id once."""
    beam_d = jnp.asarray([[1.0, jnp.inf]])
    beam_ids = jnp.asarray([[7, -1]], dtype=jnp.int32)
    beam_exp = jnp.asarray([[True, False]])
    cand_d = jnp.asarray([[0.5, 0.5, 2.0, jnp.inf]])
    cand_ids = jnp.asarray([[3, 3, 3, 3]], dtype=jnp.int32)
    ids, d, exp, keep = ops.beam_merge(
        beam_d, beam_ids, beam_exp, cand_d, cand_ids, n=10, use_ref=True)
    np.testing.assert_array_equal(np.asarray(keep), [[True, False, False, False]])
    np.testing.assert_array_equal(np.asarray(ids), [[3, 7]])
    np.testing.assert_array_equal(np.asarray(d), [[0.5, 1.0]])
    np.testing.assert_array_equal(np.asarray(exp), [[False, True]])


@pytest.mark.parametrize("bq,bc,d", [(4, 9, 8), (65, 200, 48)])
def test_int8dist_matches_ref_and_f32(bq, bc, d):
    q = _arr((bq, d))
    c = _arr((bc, d))
    cq, cs = ops.quantize_int8(c)
    got = np.asarray(ops.int8_l2dist(q, cq, cs))
    want = np.asarray(ref.int8_l2dist_ref(q, cq, cs))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # quantization error vs exact f32 distances stays small & relative
    exact = np.asarray(ref.l2dist_ref(q, c))
    rel = np.abs(got - exact) / np.maximum(exact, 1e-3)
    assert np.median(rel) < 0.05


def test_quantize_int8_bounds():
    v = _arr((20, 16))
    q, scale = ops.quantize_int8(v)
    assert q.dtype == jnp.int8
    recon = np.asarray(q, dtype=np.float32) * np.asarray(scale)[:, None]
    err = np.max(np.abs(recon - np.asarray(v)))
    assert err <= np.max(np.abs(np.asarray(v))) / 127.0 * 0.51 + 1e-6
