"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs pure-jnp
oracles, across shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("bq,bc,d", [
    (1, 1, 4), (7, 33, 16), (128, 128, 64), (37, 215, 70), (130, 50, 200),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_l2dist_matches_ref(bq, bc, d, dtype):
    q = _arr((bq, d), dtype)
    c = _arr((bc, d), dtype)
    got = ops.l2dist(q, c)
    want = ref.l2dist_ref(q, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_l2dist_is_true_squared_distance():
    q = _arr((5, 12))
    c = _arr((9, 12))
    got = np.asarray(ops.l2dist(q, c))
    brute = np.sum(
        (np.asarray(q)[:, None, :] - np.asarray(c)[None, :, :]) ** 2, axis=-1
    )
    np.testing.assert_allclose(got, brute, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,e,d", [(1, 1, 4), (3, 17, 8), (8, 128, 32), (5, 200, 64)])
def test_filter_dist_matches_ref(b, e, d):
    q = _arr((b, d))
    cand = _arr((b, e, d))
    labels = jnp.asarray(RNG.integers(0, 12, size=(b, e, 4)).astype(np.int32))
    state = jnp.asarray(RNG.integers(0, 12, size=(b, 2)).astype(np.int32))
    ids = jnp.asarray(RNG.integers(-1, 40, size=(b, e)).astype(np.int32))
    got = np.asarray(ops.filter_dist(q, cand, labels, state, ids))
    want = np.asarray(ref.filter_dist_ref(q, cand, labels, state, ids))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_label_semantics():
    """a in [l, r] and c in [b, e] — closed on both ends (paper §IV-A)."""
    q = jnp.zeros((1, 4))
    cand = jnp.ones((1, 3, 4))
    #               active       a==r boundary   b > c (inactive)
    labels = jnp.asarray([[[0, 5, 0, 5], [2, 2, 0, 5], [0, 5, 3, 5]]], dtype=jnp.int32)
    state = jnp.asarray([[2, 2]], dtype=jnp.int32)
    ids = jnp.asarray([[0, 1, 2]], dtype=jnp.int32)
    out = np.asarray(ops.filter_dist(q, cand, labels, state, ids))
    assert np.isfinite(out[0, 0]) and np.isfinite(out[0, 1])
    assert np.isinf(out[0, 2])


def _gather_case(n, b, c, d, seed=0):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    norms = jnp.sum(table * table, axis=1)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, n, size=(b, c)).astype(np.int32))
    labels = jnp.asarray(rng.integers(0, 12, size=(b, c, 4)).astype(np.int32))
    state = jnp.asarray(rng.integers(0, 12, size=(b, 2)).astype(np.int32))
    W = (n + 31) // 32
    vis = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(b, W), dtype=np.uint64).astype(np.uint32)
    )
    return table, norms, q, ids, labels, state, vis


@pytest.mark.parametrize("n,b,c,d", [
    (33, 1, 5, 4),        # B=1, n not a multiple of 32 (bitmap tail word)
    (100, 3, 24, 7),      # odd D
    (200, 4, 130, 16),    # C not a multiple of the tile
    (513, 2, 260, 32),    # multi-tile with n % 32 != 0
])
def test_filter_dist_gather_matches_ref(n, b, c, d):
    table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d)
    got = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis)
    )
    want = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis,
                               use_ref=True)
    )
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_gather_small_tile_boundaries():
    """Direct kernel call with te=8: 3 tiles + padded tail exercises the
    double-buffered DMA pipeline across tile steps."""
    from repro.kernels.filter_dist import filter_dist_gather_pallas

    n, b, c, d = 75, 2, 20, 12
    table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed=5)
    safe = jnp.clip(ids, 0, n - 1)
    g_norms = norms[safe]
    g_words = jnp.take_along_axis(vis, safe >> 5, axis=1)
    g_scales = jnp.ones_like(g_norms)
    got = np.asarray(filter_dist_gather_pallas(
        table, q, ids, labels, state, g_norms, g_words, g_scales,
        interpret=True, te=8,
    ))
    want = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis,
                               use_ref=True)
    )
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


def test_filter_dist_gather_all_invalid_tile():
    """A tile of nothing but -1 padding must come back all +inf (and the
    row-0 fetches it degenerates to must not affect other tiles)."""
    n, b, c, d = 64, 2, 16, 8
    table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed=7)
    ids = jnp.full((b, c), -1, jnp.int32)
    got = np.asarray(
        ops.filter_dist_gather(table, norms, q, ids, labels, state, vis)
    )
    assert np.all(np.isinf(got))


def test_filter_dist_gather_visited_bitmap_semantics():
    """Bit i>>5 : i&31 set => candidate i suppressed; includes the tail word
    of an n that is not a multiple of 32."""
    n, d = 45, 8            # words: [32, 13-bit tail]
    table = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    norms = jnp.sum(table * table, axis=1)
    q = jnp.zeros((1, d), jnp.float32)
    ids = jnp.asarray([[3, 31, 32, 44]], dtype=jnp.int32)
    labels = jnp.zeros((1, 4, 4), jnp.int32)
    labels = labels.at[..., 1].set(10).at[..., 3].set(10)   # wide-open rects
    state = jnp.asarray([[5, 5]], jnp.int32)
    vis = np.zeros((1, 2), np.uint32)
    vis[0, 0] = (np.uint32(1) << 31) | np.uint32(1 << 3)    # marks 31 and 3
    vis[0, 1] = np.uint32(1 << (44 - 32))                   # marks 44 (tail)
    for use_ref in (True, False):
        out = np.asarray(ops.filter_dist_gather(
            table, norms, q, ids, labels, state, jnp.asarray(vis),
            use_ref=use_ref,
        ))
        assert np.isinf(out[0, 0]) and np.isinf(out[0, 1])   # 3, 31 visited
        assert np.isfinite(out[0, 2])                        # 32 clear
        assert np.isinf(out[0, 3])                           # 44 visited


@pytest.mark.slow
def test_filter_dist_gather_exhaustive_sweep():
    """Randomized shape sweep (marked slow): every combination of B=1/odd
    D/tile-straddling C/bitmap-tail n across several seeds."""
    cases = [
        (n, b, c, d, seed)
        for n in (31, 64, 257)
        for b in (1, 5)
        for c in (3, 129)
        for d in (6, 32)
        for seed in (0, 1)
    ]
    for n, b, c, d, seed in cases:
        table, norms, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed)
        got = np.asarray(
            ops.filter_dist_gather(table, norms, q, ids, labels, state, vis)
        )
        want = np.asarray(
            ops.filter_dist_gather(table, norms, q, ids, labels, state, vis,
                                   use_ref=True)
        )
        fin = np.isfinite(want)
        np.testing.assert_array_equal(np.isfinite(got), fin, err_msg=str((n, b, c, d)))
        np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4,
                                   err_msg=str((n, b, c, d)))


def test_filter_dist_gather_int8_scales():
    n, b, c, d = 90, 3, 33, 16
    table, _, q, ids, labels, state, vis = _gather_case(n, b, c, d, seed=9)
    tq, sc = ops.quantize_int8(table)
    deq = tq.astype(jnp.float32) * sc[:, None]
    norms = jnp.sum(deq * deq, axis=1)
    got = np.asarray(ops.filter_dist_gather(
        tq, norms, q, ids, labels, state, vis, scales=sc,
    ))
    want = np.asarray(ops.filter_dist_gather(
        tq, norms, q, ids, labels, state, vis, scales=sc, use_ref=True,
    ))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("bq,bc,d", [(4, 9, 8), (65, 200, 48)])
def test_int8dist_matches_ref_and_f32(bq, bc, d):
    q = _arr((bq, d))
    c = _arr((bc, d))
    cq, cs = ops.quantize_int8(c)
    got = np.asarray(ops.int8_l2dist(q, cq, cs))
    want = np.asarray(ref.int8_l2dist_ref(q, cq, cs))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # quantization error vs exact f32 distances stays small & relative
    exact = np.asarray(ref.l2dist_ref(q, c))
    rel = np.abs(got - exact) / np.maximum(exact, 1e-3)
    assert np.median(rel) < 0.05


def test_quantize_int8_bounds():
    v = _arr((20, 16))
    q, scale = ops.quantize_int8(v)
    assert q.dtype == jnp.int8
    recon = np.asarray(q, dtype=np.float32) * np.asarray(scale)[:, None]
    err = np.max(np.abs(recon - np.asarray(v)))
    assert err <= np.max(np.abs(np.asarray(v))) / 127.0 * 0.51 + 1e-6
